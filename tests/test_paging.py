"""Paged KV cache: PageTable bookkeeping, paged==unpaged decode streams,
per-page spill metering, tenant quotas, SRPT/deadline scheduling, and the
derive_cache_shape page/0-batch fixes.

The trace drivers (`run_table_trace` / `run_scheduler_trace`) are shared
with the hypothesis property suite (tests/test_serve_properties.py); here
they run on seeded-random traces so the machinery is exercised even when
hypothesis is not installed.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig
from repro.configs.base import ShapeConfig
from repro.models import transformer as tfm
from repro.models.model import build_model
from repro.serve.engine import Engine, Request
from repro.serve.paging import PageError, PageTable, SharedPayload
from repro.serve.quota import (QuotaManager, TenantQuota, parse_quota_spec)
from repro.serve.scheduler import FairScheduler, build_scheduler
from repro.serve.session import Session

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# PageTable unit behaviour
def test_page_table_alloc_free_cycle():
    t = PageTable(num_pages=4, page_size=8)
    pids = [t.alloc(1) for _ in range(3)]
    assert len(set(pids)) == 3 and t.num_free() == 1
    assert t.resident_pids(1) == pids
    t.check()
    assert t.free_session(1) == []          # nothing spilled -> no payloads
    assert t.num_free() == 4
    t.check()
    assert t.free_session(1) == []          # double free is a no-op


def test_page_table_exhaustion_and_lazy_evict():
    t = PageTable(num_pages=2, page_size=4)
    t.alloc(1), t.alloc(2)
    with pytest.raises(PageError):          # both pages hot
        t.alloc(3)
    log = []
    t.mark_cold(1)                          # owner 1 paused
    pid = t.alloc(3, evict=lambda sid, pos, p: log.append((sid, pos, p))
                  or f"payload{p}")
    assert log == [(1, 0, pid)]             # LRU cold page was reclaimed
    assert t.evictions == 1
    assert t.resident_pids(1) == [None]     # spilled marker
    assert t.spilled_positions(1) == [0]
    t.check()
    # resume owner 1: its page must come back via set_resident (refetch)
    t.mark_hot(1)
    assert t.readmits_free == 0             # the page was gone
    with pytest.raises(PageError):          # everything hot again
        t.set_resident(1, 0)
    t.free_session(3)
    new_pid = t.set_resident(1, 0)
    assert t.refetches == 1 and t.resident_pids(1) == [new_pid]
    t.check()


def test_page_table_copy_free_readmit():
    t = PageTable(num_pages=4, page_size=4)
    t.ensure(7, rows=9)                     # 3 pages
    t.mark_cold(7)
    assert t.num_cold() == 3
    assert t.mark_hot(7) == 3               # nothing was evicted
    assert t.readmits_free == 0             # counted only on commit...
    assert t.note_resumed(7) == 3           # ...of a successful resume
    assert t.readmits_free == 3 and t.evictions == 0
    t.check()


def test_page_table_ensure_is_idempotent():
    t = PageTable(num_pages=8, page_size=4)
    assert len(t.ensure(1, rows=10)) == 3
    assert t.ensure(1, rows=10) == []
    assert t.ensure(1, rows=12) == []       # still 3 pages
    assert len(t.ensure(1, rows=13)) == 1
    assert t.pages_for(1) == 1 and t.pages_for(4) == 1 and t.pages_for(5) == 2


# ---------------------------------------------------------------------------
# trace drivers (shared with tests/test_serve_properties.py)
def run_table_trace(ops, num_pages=6, page_size=4):
    """Drive a PageTable through (op, sid) steps with a fake spill ledger.

    Model: sessions own rows; 'pause' marks cold, 'resume' re-homes
    spilled positions, 'free' retires, 'share' binds another session's
    resident page read-only (prefix-cache hit) and 'fork' models the
    copy-on-write divergence: a share immediately followed by a private
    allocation for the diverging tail.  After every step the table's
    internal invariants are checked and the spill ledger is cross-checked:
    a page fetched on resume must return exactly the payload its eviction
    stored (for a shared page: the ONE payload every holder references),
    and metered transfers must equal the table's counters.
    """
    t = PageTable(num_pages=num_pages, page_size=page_size)
    ledger = set()                          # outstanding spill payloads
    stashes, fetches = [], []

    def evict_cb(sid, pos, pid):
        payload = ("page", sid, pos, pid, len(stashes))
        ledger.add(payload)
        stashes.append(payload)
        return payload

    def share_donor(sid):
        """A resident pid of some other session that sid doesn't hold."""
        for other in t.sessions():
            if other == sid:
                continue
            for pid in t.resident_pids(other):
                if pid is not None and pid not in t.resident_pids(sid):
                    return pid
        return None

    state = {}                              # sid -> "live" | "paused"
    for op, sid in ops:
        if op == "grow" and state.get(sid) == "live":
            rows = (t.holds(sid) * page_size) + 1
            try:
                t.ensure(sid, rows, evict_cb)
            except PageError:
                pass                        # all hot: legal, nothing changed
        elif op in ("share", "fork") and state.get(sid) != "paused":
            pid = share_donor(sid)
            if pid is not None:
                t.share(sid, pid)
                state[sid] = "live"
                if op == "fork":            # diverging tail: private page
                    try:
                        t.alloc(sid, evict_cb)
                    except PageError:
                        pass
        elif op == "pause" and state.get(sid) == "live":
            t.mark_cold(sid)
            state[sid] = "paused"
        elif op == "resume" and state.get(sid) == "paused":
            t.mark_hot(sid)
            try:
                while True:
                    # re-computed each round: refetching a shared page
                    # re-homes OTHER holders' positions in the same call
                    spilled = t.spilled_positions(sid)
                    if not spilled:
                        break
                    pos = spilled[0]
                    parked = t.entries(sid)[pos].payload
                    inner = parked.payload \
                        if isinstance(parked, SharedPayload) else parked
                    assert inner in ledger, "payload mixed up"
                    t.set_resident(sid, pos, evict_cb)
                    ledger.discard(inner)
                    fetches.append(inner)
                t.note_resumed(sid)
                state[sid] = "live"
            except PageError:
                t.mark_cold(sid)            # stay paused (engine retries)
                state[sid] = "paused"
        elif op == "free" and sid in state:
            for payload in t.free_session(sid):
                assert payload in ledger, "orphaned payload unknown"
                ledger.discard(payload)
            state.pop(sid)
        elif op == "new" and sid not in state:
            try:
                t.ensure(sid, 1, evict_cb)
                state[sid] = "live"
            except PageError:
                pass
        t.check()
    assert t.evictions == len(stashes)
    assert t.refetches == len(fetches)
    # bytes invariant: every transfer moved exactly one page
    assert t.evictions * page_size == sum(page_size for _ in stashes)
    return t, state


def test_page_table_random_traces_seeded():
    rng = random.Random(1234)
    for _ in range(25):
        ops = [(rng.choice(["new", "grow", "pause", "resume", "free"]),
                rng.randrange(5)) for _ in range(120)]
        t, state = run_table_trace(ops)
        for sid in list(state):             # drain THE trace's table
            t.free_session(sid)
            t.check()
        assert t.num_free() == t.num_pages  # whole pool recovered


def test_page_table_random_shared_traces_seeded():
    """Same recovery invariant with prefix sharing in the op mix: shared
    holds, forks, shared evictions/refetches — still no leaked frames."""
    rng = random.Random(99)
    shared_seen = 0
    for _ in range(25):
        ops = [(rng.choice(["new", "grow", "pause", "resume", "free",
                            "share", "fork"]),
                rng.randrange(5)) for _ in range(120)]
        t, state = run_table_trace(ops)
        shared_seen += t.shared_binds
        for sid in list(state):
            t.free_session(sid)
            t.check()
        assert t.num_free() == t.num_pages
    assert shared_seen > 0                  # the mix actually shared


# ---------------------------------------------------------------------------
# prefix sharing: refcounted pages, COW bookkeeping
def test_share_refcounts_and_last_holder_frees():
    t = PageTable(num_pages=4, page_size=4)
    pid = t.alloc(1)
    assert t.refcount(pid) == 1 and t.num_shared() == 0
    assert t.share(2, pid) == 0             # bound at 2's position 0
    assert t.share(3, pid) == 0
    assert t.refcount(pid) == 3 and t.num_shared() == 1
    assert t.shared_binds == 2
    t.check()
    t.free_session(1)                       # two holders survive
    assert t.refcount(pid) == 2 and t.num_free() == 3
    t.free_session(2)
    assert t.refcount(pid) == 1 and t.num_shared() == 0
    t.free_session(3)                       # last holder out: frame returns
    assert t.num_free() == 4
    t.check()


def test_share_requires_resident_page():
    t = PageTable(num_pages=2, page_size=4)
    with pytest.raises(PageError):
        t.share(1, 0)                       # nobody owns page 0 yet
    pid = t.alloc(1)
    with pytest.raises(ValueError):
        t.share(1, pid)                     # self-share would alias
    t.share(2, pid)
    with pytest.raises(ValueError):
        t.share(2, pid)                     # double bind would alias
    t.check()


def test_shared_page_evictable_only_when_all_holders_pause():
    t = PageTable(num_pages=2, page_size=4)
    pid = t.alloc(1)
    t.share(2, pid)
    t.alloc(3)
    t.mark_cold(1)                          # holder 2 still hot
    assert t.num_cold() == 0
    with pytest.raises(PageError):
        t.alloc(4, evict=lambda *a: "p")    # nothing evictable
    t.mark_cold(2)
    assert t.num_cold() == 1                # now every holder is paused
    log = []
    t.alloc(4, evict=lambda sid, pos, vpid: log.append((sid, pos, vpid))
            or "spilled-bytes")
    assert len(log) == 1                    # ONE stash for both holders
    assert t.evictions == 1
    # both holders' entries reference the one SharedPayload
    p1, p2 = t.entries(1)[0].payload, t.entries(2)[0].payload
    assert isinstance(p1, SharedPayload) and p1 is p2
    assert p1.payload == "spilled-bytes"
    assert sorted(p1.holders) == [(1, 0), (2, 0)]
    t.check()


def test_shared_refetch_rehomes_every_holder():
    t = PageTable(num_pages=2, page_size=4)
    pid = t.alloc(1)
    t.share(2, pid)
    t.alloc(3)
    t.mark_cold(1), t.mark_cold(2)
    t.alloc(4, evict=lambda *a: "bytes")    # shared page spilled once
    t.free_session(3), t.free_session(4)
    t.mark_hot(1)
    new = t.set_resident(1, 0)              # ONE fetch...
    assert t.refetches == 1
    assert t.resident_pids(1) == [new]
    assert t.resident_pids(2) == [new]      # ...re-homed holder 2 too
    assert t.refcount(new) == 2
    # holder 2 is still paused; the frame is pinned by hot holder 1
    assert t.num_cold() == 0
    t.mark_hot(2)
    assert t.spilled_positions(2) == []     # nothing left to fetch
    t.check()
    # the shared spill payload was consumed: frees orphan nothing
    assert t.free_session(1) == [] and t.free_session(2) == []
    assert t.num_free() == t.num_pages


def test_shared_payload_discarded_only_by_last_holder():
    t = PageTable(num_pages=2, page_size=4)
    pid = t.alloc(1)
    t.share(2, pid)
    t.alloc(3)
    t.mark_cold(1), t.mark_cold(2)
    t.alloc(4, evict=lambda *a: "bytes")
    assert t.free_session(1) == []          # payload still referenced by 2
    assert t.free_session(2) == ["bytes"]   # last holder surrenders it
    t.check()


def test_set_resident_on_resident_position_raises():
    t = PageTable(num_pages=2, page_size=4)
    t.alloc(1)
    with pytest.raises(ValueError):
        t.set_resident(1, 0)


def test_free_session_double_free_guard_raises():
    t = PageTable(num_pages=2, page_size=4)
    pid = t.alloc(1)
    t._free.append(pid)                     # corrupt: frame freed underfoot
    with pytest.raises(ValueError):
        t.free_session(1)


def test_claim_alias_guard_raises_value_error():
    t = PageTable(num_pages=4, page_size=4)
    t.alloc(7)
    with pytest.raises(ValueError):         # not an assert: survives -O
        t.claim(7, 1)


def test_unset_resident_rolls_back_failed_fetch():
    """Bugfix: when the spill-tier fetch dies after set_resident handed
    out a frame, the rollback must return the frame and re-park the
    position over the SAME payload so a retry can still fetch it."""
    t = PageTable(num_pages=1, page_size=4)
    t.alloc(1)
    t.mark_cold(1)
    t.alloc(2, evict=lambda *a: "bytes")    # 1's page spilled
    t.free_session(2)
    t.mark_hot(1)
    pid = t.set_resident(1, 0)
    assert t.refetches == 1
    t.unset_resident(1, 0, "bytes")         # fetch failed: roll back
    assert t.refetches == 0                 # metering undone
    assert t.spilled_positions(1) == [0]
    assert t.entries(1)[0].payload == "bytes"
    t.check()
    assert t.set_resident(1, 0) is not None  # retry succeeds
    t.check()


def test_unset_resident_rejects_spilled_position():
    t = PageTable(num_pages=1, page_size=4)
    t.alloc(1)
    t.mark_cold(1)
    t.alloc(2, evict=lambda *a: "bytes")    # 1's only page spilled
    with pytest.raises(ValueError):         # nothing to roll back
        t.unset_resident(1, 0, "bytes")


def run_scheduler_trace(name, ops, slots=2, **kwargs):
    """Drive a scheduler through submit/admit/tick/pause/retire/cancel ops,
    asserting the policy invariants the ISSUE names:

    * no session is lost or double-scheduled,
    * FCFS pops fresh sessions in arrival order,
    * SRPT never runs a longer job while a shorter one waits,
    * EDF never idles while an unmet deadline waits and always picks the
      earliest deadline.
    """
    sched = build_scheduler(name, **kwargs)
    sessions, running, waiting = [], [], set()
    fresh_pops = []

    def submit(max_new, deadline):
        req = Request(uid=len(sessions), prompt=np.zeros(2, np.int32),
                      max_new_tokens=max_new, deadline=deadline)
        s = Session(request=req, seq=len(sessions))
        sessions.append(s)
        waiting.add(s.uid)
        sched.submit(s)
        return s

    for op, a, b in ops:
        if op == "submit":
            submit(a, b)
        elif op == "admit" and len(running) < slots:
            s = sched.next_ready()
            if s is None:
                assert not any(not sessions[u].done for u in waiting), \
                    f"{name} idles while work waits"
                continue
            assert s.uid in waiting, f"double-scheduled {s.uid}"
            assert not s.done, "scheduled a finished session"
            waiting.discard(s.uid)
            live = [sessions[u] for u in waiting if not sessions[u].done]
            if name == "srpt":
                assert all(s.remaining <= w.remaining for w in live), \
                    "SRPT ran a longer job while a shorter one waited"
            if name == "deadline":
                assert all(s.deadline <= w.deadline for w in live), \
                    "EDF skipped an earlier deadline"
            if name == "fcfs" and s.preemptions == 0:
                fresh_pops.append(s.seq)
            running.append(s)
        elif op == "tick":
            sched.on_step()
            for s in running:
                s.emit(0)
        elif op == "pause" and running:
            s = running.pop(a % len(running))
            s.preemptions += 1
            waiting.add(s.uid)
            sched.requeue(s)
        elif op == "retire" and running:
            s = running.pop(a % len(running))
            s.finish("length")
            sched.on_retire(s)
        elif op == "cancel" and sessions:
            s = sessions[a % len(sessions)]
            if not s.done:
                s.cancel()
                waiting.discard(s.uid)
    # drain: every surviving session comes out exactly once — none lost
    while True:
        s = sched.next_ready()
        if s is None:
            break
        assert s.uid in waiting, f"lost or duplicated session {s.uid}"
        waiting.discard(s.uid)
    assert not any(not sessions[u].done for u in waiting), \
        f"{name} lost sessions: {waiting}"
    if name == "fcfs":
        assert fresh_pops == sorted(fresh_pops), \
            "FCFS broke arrival order for fresh sessions"
    return sched, sessions


SCHED_NAMES = ("fcfs", "priority", "fair", "srpt", "deadline")


@pytest.mark.parametrize("name", SCHED_NAMES)
def test_scheduler_random_traces_seeded(name):
    rng = random.Random(99)
    for _ in range(20):
        ops = []
        for _ in range(80):
            kind = rng.choice(["submit", "admit", "tick", "pause",
                               "retire", "cancel"])
            ops.append((kind, rng.randrange(8),
                        rng.choice([None, rng.randrange(1, 30)])))
        run_scheduler_trace(name, ops)


def test_deadline_miss_accounting_in_trace():
    ops = ([("submit", 3, 1)] +                   # deadline 1: must miss
           [("admit", 0, None)] +
           [("tick", 0, None)] * 5 +
           [("retire", 0, None)])
    sched, sessions = run_scheduler_trace("deadline", ops)
    assert sched.miss_report()["missed"] == 1
    assert sched.misses_by_tenant == {"default": 1}


# ---------------------------------------------------------------------------
# transformer paged helpers
def test_paged_pool_gather_scatter_roundtrip():
    caches = tfm.init_caches(CFG, 3, 32, jnp.float32)
    caches = jax.tree.map(
        lambda c: jax.random.normal(jax.random.PRNGKey(c.size % 89), c.shape),
        caches)
    pool, slot_tree = tfm.paged_pool(caches, 8)
    pmap = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    view = tfm.gather_pages(pool, slot_tree, pmap)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), caches, view)
    # shuffled map still round-trips through scatter
    perm = jnp.asarray(np.random.default_rng(0).permutation(12)
                       .reshape(3, 4).astype(np.int32))
    pool2 = tfm.scatter_pages(pool, view, perm)
    view2 = tfm.gather_pages(pool2, slot_tree, perm)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        tfm.split_paged(caches)[0], tfm.split_paged(view2)[0])


def test_paged_pool_rejects_bad_shapes():
    caches = tfm.init_caches(CFG, 2, 32, jnp.float32)
    with pytest.raises(ValueError):
        tfm.paged_pool(caches, 7)           # does not divide max_len
    ssm = tfm.init_caches(ARCHS["mamba2-370m"].reduced(), 2, 32, jnp.float32)
    with pytest.raises(ValueError):
        tfm.paged_pool(ssm, 8)              # pure SSM: nothing to page


# ---------------------------------------------------------------------------
# paged engine end-to-end
def _solo(m, params, prompt, n_new):
    eng = Engine(m, params, batch=1, max_len=64)
    s = eng.submit(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                           max_new_tokens=n_new))
    eng.run()
    return s.result()


def test_paged_streams_identical_to_unpaged(model_and_params):
    """Acceptance: the paged path is a pure storage change — same tokens."""
    m, params = model_and_params
    prompts = [((np.arange(4 + i, dtype=np.int32) * (i + 2) + 1)
                % CFG.vocab_size) for i in range(5)]
    want = [_solo(m, params, p, 6) for p in prompts]

    def drive(**kw):
        eng = Engine(m, params, batch=2, max_len=64, **kw)
        ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
              for i, p in enumerate(prompts)]
        eng.run()
        return eng, [s.result() for s in ss]

    for kw in ({"page_size": 64, "spill": "host", "scheduler": "srpt"},
               {"page_size": 16, "spill": "host"},
               {"page_size": 16, "pages": 3, "spill": "host",
                "scheduler": FairScheduler(quantum=2)}):
        eng, got = drive(**kw)
        assert got == want, kw
    # the last (overcommitted) run actually moved pages through the tier
    pages = eng.traffic_report()["pages"]
    assert pages["evictions"] > 0 and pages["refetches"] > 0


def test_paged_streams_identical_with_staggered_retires(model_and_params):
    """Regression: with unequal max_new_tokens a session retires mid-step
    and a queued one admits into the freed slot WITHOUT crossing a page
    boundary — a stale cached page map then gathered the newcomer's decode
    from the scratch page (silent stream corruption)."""
    m, params = model_and_params
    prompts = [((np.arange(4, dtype=np.int32) * (i + 2) + 1)
                % CFG.vocab_size) for i in range(4)]
    new_tokens = [3, 9, 4, 6]
    want = [_solo(m, params, p, n) for p, n in zip(prompts, new_tokens)]

    def drive(**kw):
        eng = Engine(m, params, batch=2, max_len=64, **kw)
        ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=n))
              for i, (p, n) in enumerate(zip(prompts, new_tokens))]
        eng.run()
        return [s.result() for s in ss]

    assert drive(page_size=16, spill="host") == want
    assert drive(page_size=16, pages=3, spill="host",
                 scheduler=FairScheduler(quantum=2)) == want


def test_deadline_ignores_unserved_sessions(model_and_params):
    """Rejected / cancelled-in-queue requests are outside the SLO — they
    must not inflate the met/missed deadline accounting."""
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=8, scheduler="deadline")
    rejected = eng.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                                  max_new_tokens=4, deadline=100))
    served = eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                                max_new_tokens=3, deadline=100))
    cancelled = eng.submit(Request(uid=2, prompt=np.arange(4, dtype=np.int32),
                                   max_new_tokens=3, deadline=100))
    cancelled.cancel()
    eng.run()
    assert rejected.finish_reason == "rejected"
    rep = eng.scheduler.miss_report()
    assert rep["met"] + rep["missed"] == 1  # only the served session counts


def test_quota_from_cli_codec_is_fleet_wide_default():
    """Regression: --page-codec must also fill named --tenant-quota
    clauses that don't pick their own codec."""
    from repro.serve.quota import quota_from_cli
    q = quota_from_cli("a:pages=8;b:codec=fp8", "int8")
    assert q.codec_for("a") == "int8"       # filled by the default
    assert q.codec_for("b") == "fp8"        # explicit choice wins
    assert q.codec_for("anyone-else") == "int8"
    assert q.quota_for("a").max_pages == 8  # caps preserved
    assert quota_from_cli(None, None) is None
    assert quota_from_cli(None, "fp8").codec_for("x") == "fp8"


def test_paged_lazy_spill_is_copy_free_without_pressure(model_and_params):
    """A full-size pool never moves a byte even under heavy preemption —
    pausing marks pages cold, resuming readmits them in place."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 scheduler=FairScheduler(quantum=2), spill="host")
    ss = [eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                             max_new_tokens=6)) for i in range(5)]
    eng.run()
    assert sum(s.preemptions for s in ss) > 0
    rep = eng.traffic_report()
    assert rep["pages"]["evictions"] == 0
    assert rep["pages"]["readmits_free"] > 0
    assert "kv_stash" not in rep            # zero spill traffic


def test_paged_spill_bytes_metering(model_and_params):
    """kv_stash bytes == evictions x (bytes of one page across the kv
    leaves) — the per-page metering invariant, end to end."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=3,
                 scheduler=FairScheduler(quantum=2), spill="host")
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(5, dtype=np.int32) + i,
                           max_new_tokens=6))
    eng.run()
    rep = eng.traffic_report()
    ev, rf = rep["pages"]["evictions"], rep["pages"]["refetches"]
    assert ev > 0 and rf > 0
    page_leaves = jax.tree_util.tree_leaves(
        tfm.page_slice(eng.cache.pool, 0))
    page_bytes = sum(x.size * x.dtype.itemsize for x in page_leaves)
    assert rep["kv_stash"]["calls"] == ev * len(page_leaves)
    assert rep["kv_stash"]["wire_bytes"] == ev * page_bytes
    assert rep["kv_fetch"]["wire_bytes"] == rf * page_bytes
    # drained: every page either free or owned by nothing
    assert eng.cache.table.sessions() == ()
    assert eng.cache.table.num_free() == eng.cache.table.num_pages


def test_paged_tenant_codec_halves_spill_bytes(model_and_params):
    m, params = model_and_params

    def spill_bytes(quota):
        eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=3,
                     scheduler=FairScheduler(quantum=2), spill="host",
                     quota=quota)
        for i in range(5):
            eng.submit(Request(uid=i,
                               prompt=np.arange(5, dtype=np.int32) + i,
                               max_new_tokens=6))
        eng.run()
        rep = eng.traffic_report()
        return (rep["kv_stash"]["wire_bytes"] / rep["pages"]["evictions"],
                [r.out_tokens for r in sorted(eng.finished,
                                              key=lambda r: r.uid)])

    raw, out_raw = spill_bytes(None)
    int8, out_int8 = spill_bytes(TenantQuota(codec="int8"))
    # int8 page payloads are half the bf16/f32 wire bytes... the reduced
    # config serves f32 caches: int8 is 1/4 of f32 (+ tiny scale overhead)
    assert int8 < raw / 1.9, (raw, int8)
    assert len(out_int8) == len(out_raw) == 5   # lossy but completes


def test_quota_sessions_defer_and_release(model_and_params):
    m, params = model_and_params
    q = QuotaManager({"A": TenantQuota(max_sessions=1)})
    eng = Engine(m, params, batch=2, max_len=64, quota=q)
    sa = [eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=4, tenant="A"))
          for i in range(3)]
    sb = eng.submit(Request(uid=9, prompt=np.arange(5, dtype=np.int32),
                            max_new_tokens=4, tenant="B"))
    eng.step()
    assert sorted(s.tenant for s in eng.cache.running()) == ["A", "B"]
    assert eng.quota_report()["A"]["sessions"] == 1
    eng.run()
    assert all(s.finish_reason == "length" for s in sa + [sb])
    assert eng.quota_report()["A"]["sessions"] == 0     # released


def test_quota_page_budget_rejects_impossible(model_and_params):
    m, params = model_and_params
    q = QuotaManager({"Z": TenantQuota(max_pages=1)})
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, quota=q,
                 spill="host")
    big = eng.submit(Request(uid=0, prompt=np.arange(20, dtype=np.int32),
                             max_new_tokens=40, tenant="Z"))
    ok = eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=4, tenant="Z"))
    eng.run()
    assert big.finish_reason == "quota"     # needs 4 pages, quota is 1
    assert ok.finish_reason == "length"     # fits: admitted normally


def test_quota_page_budget_serializes_tenant(model_and_params):
    """Two sessions of 2 pages each under a 2-page budget run one after
    the other; a second tenant is unaffected."""
    m, params = model_and_params
    q = QuotaManager({"A": TenantQuota(max_pages=2)})
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, quota=q,
                 spill="host")
    a = [eng.submit(Request(uid=i, prompt=np.arange(20, dtype=np.int32),
                            max_new_tokens=10, tenant="A"))  # 30 rows: 2 pages
         for i in range(2)]
    b = eng.submit(Request(uid=5, prompt=np.arange(20, dtype=np.int32),
                           max_new_tokens=10, tenant="B"))
    eng.step()
    tenants = sorted(s.tenant for s in eng.cache.running())
    assert tenants == ["A", "B"]            # A's 2nd waits on the budget
    eng.run()
    assert all(s.finish_reason == "length" for s in a + [b])


def test_srpt_prefers_short_jobs(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, scheduler="srpt")
    long_ = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=12))
    eng.step()                              # the long job is resident
    short = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32),
                               max_new_tokens=3))
    eng.run()
    # the short job finished first even though it arrived second
    assert [r.uid for r in eng.finished] == [1, 0]
    assert long_.preemptions >= 1           # SRPT preempted the long job


def test_deadline_scheduler_orders_and_accounts(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, scheduler="deadline")
    late = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                              max_new_tokens=4, deadline=100))
    tight = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32),
                               max_new_tokens=4, deadline=2))
    eng.run()
    assert eng.finished[0].uid == 1         # EDF ran the tight deadline first
    rep = eng.scheduler.miss_report()
    assert rep["missed"] >= 1 and rep["met"] >= 1


def test_paged_cancel_while_paused_frees_pages(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, page_size=16,
                 scheduler=FairScheduler(quantum=1), spill="host")
    s0 = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                            max_new_tokens=8))
    s1 = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 2,
                            max_new_tokens=8))
    eng.step()                              # s0 resident
    eng.step()                              # s0 paused (quantum), s1 in
    assert s0.slot is None and eng.cache.table.holds(0) > 0
    s0.cancel()
    eng.run()
    assert eng.cache.table.sessions() == () # pages swept, not leaked
    assert len(s1.result()) == 8


def test_paged_pool_pressure_retires_or_preempts(model_and_params):
    """A 1-page pool with a growing session: once the page is full and no
    cold page exists, the engine retires the session cache_full instead of
    deadlocking; a queued session then gets the pool."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=1,
                 spill="host")
    a = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=40))
    b = eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=4))
    eng.run()
    assert a.finish_reason == "cache_full"
    assert a.length <= 16                   # confined to the single page
    assert b.finish_reason == "length"      # admitted after a's retire
    assert eng.cache.table.num_free() == 1


def _assert_parked_sessions_hold_no_hot_pages(eng):
    """Invariant: every page owned by a non-running session is cold (in
    the eviction queue) or spilled — never hot, which would make it
    unevictable while its owner cannot use it."""
    t = eng.cache.table
    cold = set(t._cold)
    for sess in eng.sessions:
        if sess.slot is not None:
            continue
        for pid in (t.resident_pids(sess.uid)
                    if sess.uid in t._entries else []):
            if pid is not None:
                assert pid in cold, \
                    f"parked session {sess.uid} owns hot page {pid}"


def test_grow_pages_never_allocates_to_freshly_paused(model_and_params):
    """Regression: _grow_pages used to iterate a stale running() snapshot,
    so a session paused mid-loop by pressure relief still got a page
    allocated — hot, with a parked owner, hence unevictable forever."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=16, page_size=4, pages=5,
                 spill="host")
    ss = [eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=10)),
          eng.submit(Request(uid=1, prompt=np.arange(8, dtype=np.int32),
                             max_new_tokens=10))]
    for _ in range(200):
        n = eng.step()
        _assert_parked_sessions_hold_no_hot_pages(eng)
        eng.cache.table.check()
        if n == 0 and not eng.scheduler.has_waiting():
            break
    assert all(s.done for s in ss)
    assert eng.cache.table.sessions() == ()


def test_failed_admission_rolls_back_partial_pages(model_and_params):
    """Regression: a PageError mid-prepare_slot used to leave the still-
    queued session pinning hot pages it could never use or release."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=16, page_size=4, pages=4,
                 spill="host")
    a = eng.submit(Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                           max_new_tokens=4))
    eng.step()                              # a resident: 3-4 hot pages
    b = eng.submit(Request(uid=1, prompt=np.arange(12, dtype=np.int32),
                           max_new_tokens=4))
    for _ in range(200):
        # while b waits it must hold zero pages (prepare rolled back)
        if not b.done and b.slot is None:
            assert eng.cache.table.holds(1) == 0
        _assert_parked_sessions_hold_no_hot_pages(eng)
        if eng.step() == 0 and not eng.scheduler.has_waiting():
            break
    assert a.finish_reason == "length"
    assert b.finish_reason == "length"      # admitted once a released


def test_failed_resume_does_not_inflate_readmit_count(model_and_params):
    """Regression: each failed resume attempt used to re-count the
    session's surviving pages as copy-free readmits."""
    m, params = model_and_params
    from repro.serve.cache_manager import PagedKVCacheManager
    mgr = PagedKVCacheManager(m, 2, 32, page_size=16, pages=3,
                              spill="spill")
    mk = lambda uid: Session(request=Request(
        uid=uid, prompt=np.zeros(2, np.int32)), seq=uid)
    a, b = mk(0), mk(1)
    mgr.prepare_slot(0, a, rows=32)         # a: 2 pages
    mgr.bind(0, a, 32)
    mgr.pause(a)                            # both pages cold
    mgr.prepare_slot(1, b, rows=16)         # evicts a's LRU page
    mgr.bind(1, b, 16)
    assert mgr.table.evictions == 0         # 1 free page absorbed it...
    mgr.prepare_slot(1, b, rows=32)         # ...now b's growth evicts
    assert mgr.table.evictions == 1
    assert mgr.table.spilled_positions(0) == [0]
    # resume a: its surviving page readmits, the spilled one cannot be
    # re-homed (b holds every other frame hot) -> PageError, undone count
    before = mgr.table.readmits_free
    for _ in range(3):                      # retries must not inflate
        with pytest.raises(PageError):
            mgr.resume(a, 0)
    assert mgr.table.readmits_free == before
    mgr.release(b)                          # frees b's frames
    mgr.resume(a, 0)
    assert mgr.table.readmits_free == before + 1    # one true readmit
    assert mgr.table.refetches == 1
    mgr.table.check()


def test_failed_fetch_mid_resume_reparks_position(model_and_params):
    """Bugfix: a spill-tier fetch dying AFTER set_resident handed out a
    frame used to leave the position resident over an unfilled frame —
    the rolled-back position must stay spilled (same payload) and a
    retry with a healed tier must succeed."""
    m, params = model_and_params
    from repro.serve.cache_manager import PagedKVCacheManager
    mgr = PagedKVCacheManager(m, 2, 32, page_size=16, pages=3,
                              spill="spill")
    mk = lambda uid: Session(request=Request(
        uid=uid, prompt=np.zeros(2, np.int32)), seq=uid)
    a, b = mk(0), mk(1)
    mgr.prepare_slot(0, a, rows=32)         # a: 2 pages
    mgr.bind(0, a, 32)
    mgr.pause(a)
    mgr.prepare_slot(1, b, rows=32)         # free page + evict one of a's
    mgr.bind(1, b, 32)
    assert mgr.table.spilled_positions(0) == [0]
    mgr.release(b)
    real_fetch = mgr.spill_runtime.fetch
    calls = {"n": 0}

    def flaky_fetch(*args, **kw):
        calls["n"] += 1
        if calls["n"] == 2:                 # die mid-tree (leaf 2 of N)
            raise RuntimeError("spill tier glitch")
        return real_fetch(*args, **kw)

    mgr.spill_runtime.fetch = flaky_fetch
    with pytest.raises(RuntimeError):
        mgr.resume(a, 0)
    # rolled back: still spilled over the intact payload, fetch un-metered
    assert mgr.table.spilled_positions(0) == [0]
    assert mgr.table.refetches == 0
    assert mgr.table.entries(0)[0].payload is not None
    mgr.table.check()
    mgr.spill_runtime.fetch = real_fetch    # tier heals: retry works
    mgr.resume(a, 0)
    assert mgr.table.spilled_positions(0) == []
    assert mgr.table.refetches == 1
    mgr.table.check()


@pytest.mark.parametrize("codec", [None, "fp8", "int8"])
def test_shared_page_spill_refetch_roundtrip_codecs(model_and_params, codec):
    """A SHARED page through the real spill tier, per codec: evicted once
    (one stash funds every holder), refetched once (re-homing all of
    them), and the bytes that come back are the codec's round-trip of the
    frame that left — table invariants checked after every step.  (The
    hypothesis suite drives the same share/fork machinery through random
    traces; this pins the array/codec surgery deterministically.)"""
    from repro.core.compress import decode_tensor, encode_tensor, get_codec
    from repro.serve.cache_manager import PagedKVCacheManager
    m, _ = model_and_params
    mgr = PagedKVCacheManager(m, 2, 32, page_size=16, pages=3,
                              spill="spill",
                              codec_for=lambda tenant: codec)
    mk = lambda uid: Session(request=Request(
        uid=uid, prompt=np.zeros(2, np.int32)), seq=uid)
    a, b, c = mk(0), mk(1), mk(2)
    mgr.prepare_slot(0, a, rows=16)         # a: one private page
    mgr.bind(0, a, 16)
    pid = mgr.table.resident_pids(0)[0]
    # fill the frame with deterministic non-trivial bytes
    proto = tfm.page_slice(mgr.pool, pid)
    filled = jax.tree_util.tree_map(
        lambda x: (jnp.arange(x.size, dtype=jnp.float32)
                   .reshape(x.shape) % 7 - 3).astype(x.dtype), proto)
    mgr.pool = tfm.page_insert(mgr.pool, filled, pid)
    # b shares the page read-only (what prepare_slot does on a hit)
    mgr._sessions[1] = b
    mgr._codec_by_uid[1] = codec
    mgr.table.share(1, pid)
    assert mgr.table.refcount(pid) == 2
    mgr.table.check()
    mgr.pause(a)                            # a paused...
    mgr.table.mark_cold(1)                  # ...and so is sharer b
    mgr.table.check()
    stash_before = mgr.spill_runtime.traffic_report().get(
        "kv_stash", {"calls": 0})["calls"]
    mgr.prepare_slot(1, c, rows=48)         # 2 free frames + evict shared
    mgr.bind(1, c, 48)
    assert mgr.table.evictions == 1         # ONE spill for both holders
    from repro.serve.paging import SharedPayload as SP
    parked = mgr.table.entries(0)[0].payload
    assert isinstance(parked, SP)
    assert mgr.table.entries(1)[0].payload is parked
    n_leaves = len(jax.tree_util.tree_leaves(proto))
    stash_calls = mgr.spill_runtime.traffic_report()["kv_stash"]["calls"]
    assert stash_calls - stash_before == n_leaves   # one page's leaves
    mgr.table.check()
    mgr.release(c)                          # room to come back
    mgr.resume(a, 0)                        # ONE fetch re-homes b too
    assert mgr.table.refetches == 1
    new_pid = mgr.table.resident_pids(0)[0]
    assert mgr.table.resident_pids(1) == [new_pid]
    assert mgr.table.refcount(new_pid) == 2
    mgr.table.check()
    # bytes round-trip: exactly the codec's encode->decode of what left
    got = tfm.page_slice(mgr.pool, new_pid)
    cdc = get_codec(codec) if codec else None
    for want_leaf, got_leaf in zip(jax.tree_util.tree_leaves(filled),
                                   jax.tree_util.tree_leaves(got)):
        if cdc is not None and cdc.applies_to(want_leaf):
            q, scale = encode_tensor(cdc, want_leaf, interpret=True)
            want_leaf = decode_tensor(cdc, q, scale, want_leaf.dtype,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(want_leaf),
                                      np.asarray(got_leaf))


# ---------------------------------------------------------------------------
# prefix sharing through the real engine
def _shared_prefix_prompts(n=4, head_len=20, tail_len=12):
    # head crosses one full page (rows 0-15) and diverges INSIDE the
    # second registered page (row 20 of 16..31): hits page 0, forks page 1
    head = (np.arange(head_len, dtype=np.int32) * 3 + 5) % CFG.vocab_size
    return [np.concatenate([
        head, (np.arange(tail_len, dtype=np.int32) * (i + 2) + i)
        % CFG.vocab_size]).astype(np.int32) for i in range(n)]


def test_prefix_share_streams_identical_and_hit(model_and_params):
    """Acceptance: --prefix-share is a pure storage optimisation — the
    streams match the sharing-off and unpaged runs bit-for-bit while the
    prefix cache actually hits (shared binds + forks observed)."""
    m, params = model_and_params
    prompts = _shared_prefix_prompts()
    want = [_solo(m, params, p, 6) for p in prompts]

    def drive(**kw):
        eng = Engine(m, params, batch=2, max_len=64, spill="host", **kw)
        ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
              for i, p in enumerate(prompts)]
        eng.run()
        return eng, [s.result() for s in ss]

    _, base = drive(page_size=16)
    eng, got = drive(page_size=16, prefix_share=True)
    assert got == want and base == want
    rep = eng.traffic_report()["prefix"]
    assert rep["enabled"] and rep["hits"] > 0 and rep["forks"] > 0
    assert rep["hit_rate"] > 0
    assert eng.cache.table.shared_binds > 0
    eng.cache.table.check()


def test_prefix_share_identical_under_eviction_pressure(model_and_params):
    """Shared pages spilling once and re-homing on refetch must not
    perturb the streams even when the overcommitted pool thrashes."""
    m, params = model_and_params
    prompts = _shared_prefix_prompts()
    want = [_solo(m, params, p, 6) for p in prompts]
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=4,
                 spill="host", prefix_share=True,
                 scheduler=FairScheduler(quantum=2))
    ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
          for i, p in enumerate(prompts)]
    eng.run()
    assert [s.result() for s in ss] == want
    eng.cache.table.check()


def test_prefix_share_charges_only_private_pages(model_and_params):
    """Quota: pages bound read-only from the prefix cache were already
    paid for by the donor — a matching session is charged less."""
    m, params = model_and_params
    prompts = _shared_prefix_prompts(n=2)
    quota = QuotaManager({"default": TenantQuota(max_pages=64)})
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 spill="host", prefix_share=True, quota=quota)
    ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
          for i, p in enumerate(prompts)]
    eng.step()                              # both admitted together
    used = quota.usage()["default"]["pages"]
    # solo demand: ceil(34/16)=3 pages each; the second session matched
    # at least the first full prefix page, so the pair charged < 6
    assert used < 6
    eng.run()
    assert all(s.finish_reason == "length" for s in ss)


def test_overcommitted_pool_is_physically_smaller(model_and_params):
    """pages=N must shrink the resident pool itself (the paper's pooled-
    capacity saving), not just simulate eviction pressure."""
    m, _ = model_and_params
    from repro.serve.cache_manager import PagedKVCacheManager
    full = PagedKVCacheManager(m, 2, 64, page_size=16, spill=None)
    small = PagedKVCacheManager(m, 2, 64, page_size=16, pages=3,
                                spill=None)
    fb = sum(x.size for x in jax.tree_util.tree_leaves(full.pool))
    sb = sum(x.size for x in jax.tree_util.tree_leaves(small.pool))
    assert fb * 4 == sb * 9             # 8+1 frames vs 3+1 frames
    assert small.scratch_id == 3 and full.scratch_id == 8
    with pytest.raises(ValueError):
        PagedKVCacheManager(m, 2, 64, page_size=16, pages=9, spill=None)


def test_paged_engine_with_temperature_sampling(model_and_params):
    """Non-greedy sampling through the paged path exercises the PRNG
    branch; the stream stays inside the vocab and completes."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 temperature=0.8, seed=7, spill="host")
    s = eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                           max_new_tokens=5))
    eng.run()
    assert len(s.result()) == 5
    assert all(0 <= t < CFG.vocab_size for t in s.result())


# ---------------------------------------------------------------------------
# derive_cache_shape: page sizing + the explicit-0/None regression
def test_derive_cache_shape_batch_zero_means_auto(model_and_params):
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    auto = derive_cache_shape(m.cfg, m.runtime, None, None)
    zero = derive_cache_shape(m.cfg, m.runtime, 0, 0)
    assert zero == auto                     # 0 no longer leaks through
    assert zero["batch"] >= 1 and zero["max_len"] >= 16


def test_derive_cache_shape_joint_solve_tiny_budget(model_and_params):
    """batch=None, max_len=None at a starvation budget: the halving loop
    floors at 16 rows and the packer still returns a sane >=1 slot."""
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    sized = derive_cache_shape(m.cfg, m.runtime, None, None,
                               hbm_frac=1e-12)
    assert sized["batch"] == 1 and sized["max_len"] == 16
    assert sized["report"]["capacity_bytes"] > 0
    # paged twin: the floor rounds to whole pages
    paged = derive_cache_shape(m.cfg, m.runtime, None, None,
                               hbm_frac=1e-12, page_size=8)
    assert paged["max_len"] % 8 == 0 and paged["max_len"] >= 8
    assert paged["report"]["num_pages"] == \
        paged["batch"] * paged["report"]["pages_per_slot"]


def test_derive_cache_shape_page_rounding(model_and_params):
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    up = derive_cache_shape(m.cfg, m.runtime, 2, 50, page_size=16)
    assert up["max_len"] == 64              # explicit max_len rounds UP
    assert up["report"]["pages_per_slot"] == 4
    with pytest.raises(ValueError):
        derive_cache_shape(m.cfg, m.runtime, 2, 64, page_size=0)


# ---------------------------------------------------------------------------
# quota plumbing
def test_parse_quota_spec_grammar():
    per, default = parse_quota_spec("pages=16,sessions=2")
    assert per == {} and default == TenantQuota(16, 2, None)
    per, default = parse_quota_spec(
        "interactive:sessions=4;batch:pages=8,codec=int8")
    assert per["interactive"] == TenantQuota(None, 4, None)
    assert per["batch"] == TenantQuota(8, None, "int8")
    assert default == TenantQuota()
    with pytest.raises(ValueError):
        parse_quota_spec("pages")
    with pytest.raises(ValueError):
        parse_quota_spec("rows=4")
    with pytest.raises(KeyError):
        parse_quota_spec("codec=zstd")      # unknown codec fails fast


def test_quota_manager_ledger():
    q = QuotaManager({"a": TenantQuota(max_pages=4, max_sessions=2)})
    assert q.can_admit("a", 3) and q.admissible("a", 4)
    assert not q.admissible("a", 5)
    q.admit("a", 3)
    assert not q.can_admit("a", 2)          # page budget
    q.admit("a", 1)
    assert not q.can_admit("a", 0)          # session cap
    q.release("a", 3)
    q.release("a", 1)
    assert q.usage()["a"] == {"sessions": 0, "pages": 0}
    assert q.can_admit("other", 10**6)      # default quota is unlimited
    assert "quota[" in q.describe()
