"""Paged KV cache: PageTable bookkeeping, paged==unpaged decode streams,
per-page spill metering, tenant quotas, SRPT/deadline scheduling, and the
derive_cache_shape page/0-batch fixes.

The trace drivers (`run_table_trace` / `run_scheduler_trace`) are shared
with the hypothesis property suite (tests/test_serve_properties.py); here
they run on seeded-random traces so the machinery is exercised even when
hypothesis is not installed.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig
from repro.configs.base import ShapeConfig
from repro.models import transformer as tfm
from repro.models.model import build_model
from repro.serve.engine import Engine, Request
from repro.serve.paging import PageError, PageTable
from repro.serve.quota import (QuotaManager, TenantQuota, parse_quota_spec)
from repro.serve.scheduler import FairScheduler, build_scheduler
from repro.serve.session import Session

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# PageTable unit behaviour
def test_page_table_alloc_free_cycle():
    t = PageTable(num_pages=4, page_size=8)
    pids = [t.alloc(1) for _ in range(3)]
    assert len(set(pids)) == 3 and t.num_free() == 1
    assert t.resident_pids(1) == pids
    t.check()
    assert t.free_session(1) == []          # nothing spilled -> no payloads
    assert t.num_free() == 4
    t.check()
    assert t.free_session(1) == []          # double free is a no-op


def test_page_table_exhaustion_and_lazy_evict():
    t = PageTable(num_pages=2, page_size=4)
    t.alloc(1), t.alloc(2)
    with pytest.raises(PageError):          # both pages hot
        t.alloc(3)
    log = []
    t.mark_cold(1)                          # owner 1 paused
    pid = t.alloc(3, evict=lambda sid, pos, p: log.append((sid, pos, p))
                  or f"payload{p}")
    assert log == [(1, 0, pid)]             # LRU cold page was reclaimed
    assert t.evictions == 1
    assert t.resident_pids(1) == [None]     # spilled marker
    assert t.spilled_positions(1) == [0]
    t.check()
    # resume owner 1: its page must come back via set_resident (refetch)
    t.mark_hot(1)
    assert t.readmits_free == 0             # the page was gone
    with pytest.raises(PageError):          # everything hot again
        t.set_resident(1, 0)
    t.free_session(3)
    new_pid = t.set_resident(1, 0)
    assert t.refetches == 1 and t.resident_pids(1) == [new_pid]
    t.check()


def test_page_table_copy_free_readmit():
    t = PageTable(num_pages=4, page_size=4)
    t.ensure(7, rows=9)                     # 3 pages
    t.mark_cold(7)
    assert t.num_cold() == 3
    assert t.mark_hot(7) == 3               # nothing was evicted
    assert t.readmits_free == 0             # counted only on commit...
    assert t.note_resumed(7) == 3           # ...of a successful resume
    assert t.readmits_free == 3 and t.evictions == 0
    t.check()


def test_page_table_ensure_is_idempotent():
    t = PageTable(num_pages=8, page_size=4)
    assert len(t.ensure(1, rows=10)) == 3
    assert t.ensure(1, rows=10) == []
    assert t.ensure(1, rows=12) == []       # still 3 pages
    assert len(t.ensure(1, rows=13)) == 1
    assert t.pages_for(1) == 1 and t.pages_for(4) == 1 and t.pages_for(5) == 2


# ---------------------------------------------------------------------------
# trace drivers (shared with tests/test_serve_properties.py)
def run_table_trace(ops, num_pages=6, page_size=4):
    """Drive a PageTable through (op, sid) steps with a fake spill ledger.

    Model: sessions own rows; 'pause' marks cold, 'resume' re-homes
    spilled positions, 'free' retires.  After every step the table's
    internal invariants are checked and the spill ledger is cross-checked:
    a page fetched on resume must return exactly the payload its eviction
    stored, and metered transfers must equal the table's counters.
    """
    t = PageTable(num_pages=num_pages, page_size=page_size)
    ledger = {}                             # (sid, pos) -> payload
    stashes, fetches = [], []

    def evict_cb(sid, pos, pid):
        payload = ("page", sid, pos, pid)
        ledger[(sid, pos)] = payload
        stashes.append(payload)
        return payload

    state = {}                              # sid -> "live" | "paused"
    for op, sid in ops:
        if op == "grow" and state.get(sid) == "live":
            rows = (t.holds(sid) * page_size) + 1
            try:
                t.ensure(sid, rows, evict_cb)
            except PageError:
                pass                        # all hot: legal, nothing changed
        elif op == "pause" and state.get(sid) == "live":
            t.mark_cold(sid)
            state[sid] = "paused"
        elif op == "resume" and state.get(sid) == "paused":
            t.mark_hot(sid)
            try:
                for pos in t.spilled_positions(sid):
                    want = ledger[(sid, pos)]
                    entry = t.entries(sid)[pos]
                    assert entry.payload == want, "payload mixed up"
                    t.set_resident(sid, pos, evict_cb)
                    ledger.pop((sid, pos))
                    fetches.append(want)
                t.note_resumed(sid)
                state[sid] = "live"
            except PageError:
                t.mark_cold(sid)            # stay paused (engine retries)
                state[sid] = "paused"
        elif op == "free" and sid in state:
            for payload in t.free_session(sid):
                ledger.pop((payload[1], payload[2]))
            state.pop(sid)
        elif op == "new" and sid not in state:
            try:
                t.ensure(sid, 1, evict_cb)
                state[sid] = "live"
            except PageError:
                pass
        t.check()
    assert t.evictions == len(stashes)
    assert t.refetches == len(fetches)
    # bytes invariant: every transfer moved exactly one page
    assert t.evictions * page_size == sum(page_size for _ in stashes)
    return t, state


def test_page_table_random_traces_seeded():
    rng = random.Random(1234)
    for _ in range(25):
        ops = [(rng.choice(["new", "grow", "pause", "resume", "free"]),
                rng.randrange(5)) for _ in range(120)]
        t, state = run_table_trace(ops)
        for sid in list(state):             # drain THE trace's table
            t.free_session(sid)
            t.check()
        assert t.num_free() == t.num_pages  # whole pool recovered


def run_scheduler_trace(name, ops, slots=2, **kwargs):
    """Drive a scheduler through submit/admit/tick/pause/retire/cancel ops,
    asserting the policy invariants the ISSUE names:

    * no session is lost or double-scheduled,
    * FCFS pops fresh sessions in arrival order,
    * SRPT never runs a longer job while a shorter one waits,
    * EDF never idles while an unmet deadline waits and always picks the
      earliest deadline.
    """
    sched = build_scheduler(name, **kwargs)
    sessions, running, waiting = [], [], set()
    fresh_pops = []

    def submit(max_new, deadline):
        req = Request(uid=len(sessions), prompt=np.zeros(2, np.int32),
                      max_new_tokens=max_new, deadline=deadline)
        s = Session(request=req, seq=len(sessions))
        sessions.append(s)
        waiting.add(s.uid)
        sched.submit(s)
        return s

    for op, a, b in ops:
        if op == "submit":
            submit(a, b)
        elif op == "admit" and len(running) < slots:
            s = sched.next_ready()
            if s is None:
                assert not any(not sessions[u].done for u in waiting), \
                    f"{name} idles while work waits"
                continue
            assert s.uid in waiting, f"double-scheduled {s.uid}"
            assert not s.done, "scheduled a finished session"
            waiting.discard(s.uid)
            live = [sessions[u] for u in waiting if not sessions[u].done]
            if name == "srpt":
                assert all(s.remaining <= w.remaining for w in live), \
                    "SRPT ran a longer job while a shorter one waited"
            if name == "deadline":
                assert all(s.deadline <= w.deadline for w in live), \
                    "EDF skipped an earlier deadline"
            if name == "fcfs" and s.preemptions == 0:
                fresh_pops.append(s.seq)
            running.append(s)
        elif op == "tick":
            sched.on_step()
            for s in running:
                s.emit(0)
        elif op == "pause" and running:
            s = running.pop(a % len(running))
            s.preemptions += 1
            waiting.add(s.uid)
            sched.requeue(s)
        elif op == "retire" and running:
            s = running.pop(a % len(running))
            s.finish("length")
            sched.on_retire(s)
        elif op == "cancel" and sessions:
            s = sessions[a % len(sessions)]
            if not s.done:
                s.cancel()
                waiting.discard(s.uid)
    # drain: every surviving session comes out exactly once — none lost
    while True:
        s = sched.next_ready()
        if s is None:
            break
        assert s.uid in waiting, f"lost or duplicated session {s.uid}"
        waiting.discard(s.uid)
    assert not any(not sessions[u].done for u in waiting), \
        f"{name} lost sessions: {waiting}"
    if name == "fcfs":
        assert fresh_pops == sorted(fresh_pops), \
            "FCFS broke arrival order for fresh sessions"
    return sched, sessions


SCHED_NAMES = ("fcfs", "priority", "fair", "srpt", "deadline")


@pytest.mark.parametrize("name", SCHED_NAMES)
def test_scheduler_random_traces_seeded(name):
    rng = random.Random(99)
    for _ in range(20):
        ops = []
        for _ in range(80):
            kind = rng.choice(["submit", "admit", "tick", "pause",
                               "retire", "cancel"])
            ops.append((kind, rng.randrange(8),
                        rng.choice([None, rng.randrange(1, 30)])))
        run_scheduler_trace(name, ops)


def test_deadline_miss_accounting_in_trace():
    ops = ([("submit", 3, 1)] +                   # deadline 1: must miss
           [("admit", 0, None)] +
           [("tick", 0, None)] * 5 +
           [("retire", 0, None)])
    sched, sessions = run_scheduler_trace("deadline", ops)
    assert sched.miss_report()["missed"] == 1
    assert sched.misses_by_tenant == {"default": 1}


# ---------------------------------------------------------------------------
# transformer paged helpers
def test_paged_pool_gather_scatter_roundtrip():
    caches = tfm.init_caches(CFG, 3, 32, jnp.float32)
    caches = jax.tree.map(
        lambda c: jax.random.normal(jax.random.PRNGKey(c.size % 89), c.shape),
        caches)
    pool, slot_tree = tfm.paged_pool(caches, 8)
    pmap = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    view = tfm.gather_pages(pool, slot_tree, pmap)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), caches, view)
    # shuffled map still round-trips through scatter
    perm = jnp.asarray(np.random.default_rng(0).permutation(12)
                       .reshape(3, 4).astype(np.int32))
    pool2 = tfm.scatter_pages(pool, view, perm)
    view2 = tfm.gather_pages(pool2, slot_tree, perm)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        tfm.split_paged(caches)[0], tfm.split_paged(view2)[0])


def test_paged_pool_rejects_bad_shapes():
    caches = tfm.init_caches(CFG, 2, 32, jnp.float32)
    with pytest.raises(ValueError):
        tfm.paged_pool(caches, 7)           # does not divide max_len
    ssm = tfm.init_caches(ARCHS["mamba2-370m"].reduced(), 2, 32, jnp.float32)
    with pytest.raises(ValueError):
        tfm.paged_pool(ssm, 8)              # pure SSM: nothing to page


# ---------------------------------------------------------------------------
# paged engine end-to-end
def _solo(m, params, prompt, n_new):
    eng = Engine(m, params, batch=1, max_len=64)
    s = eng.submit(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                           max_new_tokens=n_new))
    eng.run()
    return s.result()


def test_paged_streams_identical_to_unpaged(model_and_params):
    """Acceptance: the paged path is a pure storage change — same tokens."""
    m, params = model_and_params
    prompts = [((np.arange(4 + i, dtype=np.int32) * (i + 2) + 1)
                % CFG.vocab_size) for i in range(5)]
    want = [_solo(m, params, p, 6) for p in prompts]

    def drive(**kw):
        eng = Engine(m, params, batch=2, max_len=64, **kw)
        ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
              for i, p in enumerate(prompts)]
        eng.run()
        return eng, [s.result() for s in ss]

    for kw in ({"page_size": 64, "spill": "host", "scheduler": "srpt"},
               {"page_size": 16, "spill": "host"},
               {"page_size": 16, "pages": 3, "spill": "host",
                "scheduler": FairScheduler(quantum=2)}):
        eng, got = drive(**kw)
        assert got == want, kw
    # the last (overcommitted) run actually moved pages through the tier
    pages = eng.traffic_report()["pages"]
    assert pages["evictions"] > 0 and pages["refetches"] > 0


def test_paged_streams_identical_with_staggered_retires(model_and_params):
    """Regression: with unequal max_new_tokens a session retires mid-step
    and a queued one admits into the freed slot WITHOUT crossing a page
    boundary — a stale cached page map then gathered the newcomer's decode
    from the scratch page (silent stream corruption)."""
    m, params = model_and_params
    prompts = [((np.arange(4, dtype=np.int32) * (i + 2) + 1)
                % CFG.vocab_size) for i in range(4)]
    new_tokens = [3, 9, 4, 6]
    want = [_solo(m, params, p, n) for p, n in zip(prompts, new_tokens)]

    def drive(**kw):
        eng = Engine(m, params, batch=2, max_len=64, **kw)
        ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=n))
              for i, (p, n) in enumerate(zip(prompts, new_tokens))]
        eng.run()
        return [s.result() for s in ss]

    assert drive(page_size=16, spill="host") == want
    assert drive(page_size=16, pages=3, spill="host",
                 scheduler=FairScheduler(quantum=2)) == want


def test_deadline_ignores_unserved_sessions(model_and_params):
    """Rejected / cancelled-in-queue requests are outside the SLO — they
    must not inflate the met/missed deadline accounting."""
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=8, scheduler="deadline")
    rejected = eng.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                                  max_new_tokens=4, deadline=100))
    served = eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                                max_new_tokens=3, deadline=100))
    cancelled = eng.submit(Request(uid=2, prompt=np.arange(4, dtype=np.int32),
                                   max_new_tokens=3, deadline=100))
    cancelled.cancel()
    eng.run()
    assert rejected.finish_reason == "rejected"
    rep = eng.scheduler.miss_report()
    assert rep["met"] + rep["missed"] == 1  # only the served session counts


def test_quota_from_cli_codec_is_fleet_wide_default():
    """Regression: --page-codec must also fill named --tenant-quota
    clauses that don't pick their own codec."""
    from repro.serve.quota import quota_from_cli
    q = quota_from_cli("a:pages=8;b:codec=fp8", "int8")
    assert q.codec_for("a") == "int8"       # filled by the default
    assert q.codec_for("b") == "fp8"        # explicit choice wins
    assert q.codec_for("anyone-else") == "int8"
    assert q.quota_for("a").max_pages == 8  # caps preserved
    assert quota_from_cli(None, None) is None
    assert quota_from_cli(None, "fp8").codec_for("x") == "fp8"


def test_paged_lazy_spill_is_copy_free_without_pressure(model_and_params):
    """A full-size pool never moves a byte even under heavy preemption —
    pausing marks pages cold, resuming readmits them in place."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 scheduler=FairScheduler(quantum=2), spill="host")
    ss = [eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                             max_new_tokens=6)) for i in range(5)]
    eng.run()
    assert sum(s.preemptions for s in ss) > 0
    rep = eng.traffic_report()
    assert rep["pages"]["evictions"] == 0
    assert rep["pages"]["readmits_free"] > 0
    assert "kv_stash" not in rep            # zero spill traffic


def test_paged_spill_bytes_metering(model_and_params):
    """kv_stash bytes == evictions x (bytes of one page across the kv
    leaves) — the per-page metering invariant, end to end."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=3,
                 scheduler=FairScheduler(quantum=2), spill="host")
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(5, dtype=np.int32) + i,
                           max_new_tokens=6))
    eng.run()
    rep = eng.traffic_report()
    ev, rf = rep["pages"]["evictions"], rep["pages"]["refetches"]
    assert ev > 0 and rf > 0
    page_leaves = jax.tree_util.tree_leaves(
        tfm.page_slice(eng.cache.pool, 0))
    page_bytes = sum(x.size * x.dtype.itemsize for x in page_leaves)
    assert rep["kv_stash"]["calls"] == ev * len(page_leaves)
    assert rep["kv_stash"]["wire_bytes"] == ev * page_bytes
    assert rep["kv_fetch"]["wire_bytes"] == rf * page_bytes
    # drained: every page either free or owned by nothing
    assert eng.cache.table.sessions() == ()
    assert eng.cache.table.num_free() == eng.cache.table.num_pages


def test_paged_tenant_codec_halves_spill_bytes(model_and_params):
    m, params = model_and_params

    def spill_bytes(quota):
        eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=3,
                     scheduler=FairScheduler(quantum=2), spill="host",
                     quota=quota)
        for i in range(5):
            eng.submit(Request(uid=i,
                               prompt=np.arange(5, dtype=np.int32) + i,
                               max_new_tokens=6))
        eng.run()
        rep = eng.traffic_report()
        return (rep["kv_stash"]["wire_bytes"] / rep["pages"]["evictions"],
                [r.out_tokens for r in sorted(eng.finished,
                                              key=lambda r: r.uid)])

    raw, out_raw = spill_bytes(None)
    int8, out_int8 = spill_bytes(TenantQuota(codec="int8"))
    # int8 page payloads are half the bf16/f32 wire bytes... the reduced
    # config serves f32 caches: int8 is 1/4 of f32 (+ tiny scale overhead)
    assert int8 < raw / 1.9, (raw, int8)
    assert len(out_int8) == len(out_raw) == 5   # lossy but completes


def test_quota_sessions_defer_and_release(model_and_params):
    m, params = model_and_params
    q = QuotaManager({"A": TenantQuota(max_sessions=1)})
    eng = Engine(m, params, batch=2, max_len=64, quota=q)
    sa = [eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=4, tenant="A"))
          for i in range(3)]
    sb = eng.submit(Request(uid=9, prompt=np.arange(5, dtype=np.int32),
                            max_new_tokens=4, tenant="B"))
    eng.step()
    assert sorted(s.tenant for s in eng.cache.running()) == ["A", "B"]
    assert eng.quota_report()["A"]["sessions"] == 1
    eng.run()
    assert all(s.finish_reason == "length" for s in sa + [sb])
    assert eng.quota_report()["A"]["sessions"] == 0     # released


def test_quota_page_budget_rejects_impossible(model_and_params):
    m, params = model_and_params
    q = QuotaManager({"Z": TenantQuota(max_pages=1)})
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, quota=q,
                 spill="host")
    big = eng.submit(Request(uid=0, prompt=np.arange(20, dtype=np.int32),
                             max_new_tokens=40, tenant="Z"))
    ok = eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=4, tenant="Z"))
    eng.run()
    assert big.finish_reason == "quota"     # needs 4 pages, quota is 1
    assert ok.finish_reason == "length"     # fits: admitted normally


def test_quota_page_budget_serializes_tenant(model_and_params):
    """Two sessions of 2 pages each under a 2-page budget run one after
    the other; a second tenant is unaffected."""
    m, params = model_and_params
    q = QuotaManager({"A": TenantQuota(max_pages=2)})
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, quota=q,
                 spill="host")
    a = [eng.submit(Request(uid=i, prompt=np.arange(20, dtype=np.int32),
                            max_new_tokens=10, tenant="A"))  # 30 rows: 2 pages
         for i in range(2)]
    b = eng.submit(Request(uid=5, prompt=np.arange(20, dtype=np.int32),
                           max_new_tokens=10, tenant="B"))
    eng.step()
    tenants = sorted(s.tenant for s in eng.cache.running())
    assert tenants == ["A", "B"]            # A's 2nd waits on the budget
    eng.run()
    assert all(s.finish_reason == "length" for s in a + [b])


def test_srpt_prefers_short_jobs(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, scheduler="srpt")
    long_ = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=12))
    eng.step()                              # the long job is resident
    short = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32),
                               max_new_tokens=3))
    eng.run()
    # the short job finished first even though it arrived second
    assert [r.uid for r in eng.finished] == [1, 0]
    assert long_.preemptions >= 1           # SRPT preempted the long job


def test_deadline_scheduler_orders_and_accounts(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, scheduler="deadline")
    late = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                              max_new_tokens=4, deadline=100))
    tight = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32),
                               max_new_tokens=4, deadline=2))
    eng.run()
    assert eng.finished[0].uid == 1         # EDF ran the tight deadline first
    rep = eng.scheduler.miss_report()
    assert rep["missed"] >= 1 and rep["met"] >= 1


def test_paged_cancel_while_paused_frees_pages(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, page_size=16,
                 scheduler=FairScheduler(quantum=1), spill="host")
    s0 = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                            max_new_tokens=8))
    s1 = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 2,
                            max_new_tokens=8))
    eng.step()                              # s0 resident
    eng.step()                              # s0 paused (quantum), s1 in
    assert s0.slot is None and eng.cache.table.holds(0) > 0
    s0.cancel()
    eng.run()
    assert eng.cache.table.sessions() == () # pages swept, not leaked
    assert len(s1.result()) == 8


def test_paged_pool_pressure_retires_or_preempts(model_and_params):
    """A 1-page pool with a growing session: once the page is full and no
    cold page exists, the engine retires the session cache_full instead of
    deadlocking; a queued session then gets the pool."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16, pages=1,
                 spill="host")
    a = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=40))
    b = eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=4))
    eng.run()
    assert a.finish_reason == "cache_full"
    assert a.length <= 16                   # confined to the single page
    assert b.finish_reason == "length"      # admitted after a's retire
    assert eng.cache.table.num_free() == 1


def _assert_parked_sessions_hold_no_hot_pages(eng):
    """Invariant: every page owned by a non-running session is cold (in
    the eviction queue) or spilled — never hot, which would make it
    unevictable while its owner cannot use it."""
    t = eng.cache.table
    cold = set(t._cold)
    for sess in eng.sessions:
        if sess.slot is not None:
            continue
        for pid in (t.resident_pids(sess.uid)
                    if sess.uid in t._entries else []):
            if pid is not None:
                assert pid in cold, \
                    f"parked session {sess.uid} owns hot page {pid}"


def test_grow_pages_never_allocates_to_freshly_paused(model_and_params):
    """Regression: _grow_pages used to iterate a stale running() snapshot,
    so a session paused mid-loop by pressure relief still got a page
    allocated — hot, with a parked owner, hence unevictable forever."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=16, page_size=4, pages=5,
                 spill="host")
    ss = [eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=10)),
          eng.submit(Request(uid=1, prompt=np.arange(8, dtype=np.int32),
                             max_new_tokens=10))]
    for _ in range(200):
        n = eng.step()
        _assert_parked_sessions_hold_no_hot_pages(eng)
        eng.cache.table.check()
        if n == 0 and not eng.scheduler.has_waiting():
            break
    assert all(s.done for s in ss)
    assert eng.cache.table.sessions() == ()


def test_failed_admission_rolls_back_partial_pages(model_and_params):
    """Regression: a PageError mid-prepare_slot used to leave the still-
    queued session pinning hot pages it could never use or release."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=16, page_size=4, pages=4,
                 spill="host")
    a = eng.submit(Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                           max_new_tokens=4))
    eng.step()                              # a resident: 3-4 hot pages
    b = eng.submit(Request(uid=1, prompt=np.arange(12, dtype=np.int32),
                           max_new_tokens=4))
    for _ in range(200):
        # while b waits it must hold zero pages (prepare rolled back)
        if not b.done and b.slot is None:
            assert eng.cache.table.holds(1) == 0
        _assert_parked_sessions_hold_no_hot_pages(eng)
        if eng.step() == 0 and not eng.scheduler.has_waiting():
            break
    assert a.finish_reason == "length"
    assert b.finish_reason == "length"      # admitted once a released


def test_failed_resume_does_not_inflate_readmit_count(model_and_params):
    """Regression: each failed resume attempt used to re-count the
    session's surviving pages as copy-free readmits."""
    m, params = model_and_params
    from repro.serve.cache_manager import PagedKVCacheManager
    mgr = PagedKVCacheManager(m, 2, 32, page_size=16, pages=3,
                              spill="spill")
    mk = lambda uid: Session(request=Request(
        uid=uid, prompt=np.zeros(2, np.int32)), seq=uid)
    a, b = mk(0), mk(1)
    mgr.prepare_slot(0, a, rows=32)         # a: 2 pages
    mgr.bind(0, a, 32)
    mgr.pause(a)                            # both pages cold
    mgr.prepare_slot(1, b, rows=16)         # evicts a's LRU page
    mgr.bind(1, b, 16)
    assert mgr.table.evictions == 0         # 1 free page absorbed it...
    mgr.prepare_slot(1, b, rows=32)         # ...now b's growth evicts
    assert mgr.table.evictions == 1
    assert mgr.table.spilled_positions(0) == [0]
    # resume a: its surviving page readmits, the spilled one cannot be
    # re-homed (b holds every other frame hot) -> PageError, undone count
    before = mgr.table.readmits_free
    for _ in range(3):                      # retries must not inflate
        with pytest.raises(PageError):
            mgr.resume(a, 0)
    assert mgr.table.readmits_free == before
    mgr.release(b)                          # frees b's frames
    mgr.resume(a, 0)
    assert mgr.table.readmits_free == before + 1    # one true readmit
    assert mgr.table.refetches == 1
    mgr.table.check()


def test_overcommitted_pool_is_physically_smaller(model_and_params):
    """pages=N must shrink the resident pool itself (the paper's pooled-
    capacity saving), not just simulate eviction pressure."""
    m, _ = model_and_params
    from repro.serve.cache_manager import PagedKVCacheManager
    full = PagedKVCacheManager(m, 2, 64, page_size=16, spill=None)
    small = PagedKVCacheManager(m, 2, 64, page_size=16, pages=3,
                                spill=None)
    fb = sum(x.size for x in jax.tree_util.tree_leaves(full.pool))
    sb = sum(x.size for x in jax.tree_util.tree_leaves(small.pool))
    assert fb * 4 == sb * 9             # 8+1 frames vs 3+1 frames
    assert small.scratch_id == 3 and full.scratch_id == 8
    with pytest.raises(ValueError):
        PagedKVCacheManager(m, 2, 64, page_size=16, pages=9, spill=None)


def test_paged_engine_with_temperature_sampling(model_and_params):
    """Non-greedy sampling through the paged path exercises the PRNG
    branch; the stream stays inside the vocab and completes."""
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 temperature=0.8, seed=7, spill="host")
    s = eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                           max_new_tokens=5))
    eng.run()
    assert len(s.result()) == 5
    assert all(0 <= t < CFG.vocab_size for t in s.result())


# ---------------------------------------------------------------------------
# derive_cache_shape: page sizing + the explicit-0/None regression
def test_derive_cache_shape_batch_zero_means_auto(model_and_params):
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    auto = derive_cache_shape(m.cfg, m.runtime, None, None)
    zero = derive_cache_shape(m.cfg, m.runtime, 0, 0)
    assert zero == auto                     # 0 no longer leaks through
    assert zero["batch"] >= 1 and zero["max_len"] >= 16


def test_derive_cache_shape_joint_solve_tiny_budget(model_and_params):
    """batch=None, max_len=None at a starvation budget: the halving loop
    floors at 16 rows and the packer still returns a sane >=1 slot."""
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    sized = derive_cache_shape(m.cfg, m.runtime, None, None,
                               hbm_frac=1e-12)
    assert sized["batch"] == 1 and sized["max_len"] == 16
    assert sized["report"]["capacity_bytes"] > 0
    # paged twin: the floor rounds to whole pages
    paged = derive_cache_shape(m.cfg, m.runtime, None, None,
                               hbm_frac=1e-12, page_size=8)
    assert paged["max_len"] % 8 == 0 and paged["max_len"] >= 8
    assert paged["report"]["num_pages"] == \
        paged["batch"] * paged["report"]["pages_per_slot"]


def test_derive_cache_shape_page_rounding(model_and_params):
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    up = derive_cache_shape(m.cfg, m.runtime, 2, 50, page_size=16)
    assert up["max_len"] == 64              # explicit max_len rounds UP
    assert up["report"]["pages_per_slot"] == 4
    with pytest.raises(ValueError):
        derive_cache_shape(m.cfg, m.runtime, 2, 64, page_size=0)


# ---------------------------------------------------------------------------
# quota plumbing
def test_parse_quota_spec_grammar():
    per, default = parse_quota_spec("pages=16,sessions=2")
    assert per == {} and default == TenantQuota(16, 2, None)
    per, default = parse_quota_spec(
        "interactive:sessions=4;batch:pages=8,codec=int8")
    assert per["interactive"] == TenantQuota(None, 4, None)
    assert per["batch"] == TenantQuota(8, None, "int8")
    assert default == TenantQuota()
    with pytest.raises(ValueError):
        parse_quota_spec("pages")
    with pytest.raises(ValueError):
        parse_quota_spec("rows=4")
    with pytest.raises(KeyError):
        parse_quota_spec("codec=zstd")      # unknown codec fails fast


def test_quota_manager_ledger():
    q = QuotaManager({"a": TenantQuota(max_pages=4, max_sessions=2)})
    assert q.can_admit("a", 3) and q.admissible("a", 4)
    assert not q.admissible("a", 5)
    q.admit("a", 3)
    assert not q.can_admit("a", 2)          # page budget
    q.admit("a", 1)
    assert not q.can_admit("a", 0)          # session cap
    q.release("a", 3)
    q.release("a", 1)
    assert q.usage()["a"] == {"sessions": 0, "pages": 0}
    assert q.can_admit("other", 10**6)      # default quota is unlimited
    assert "quota[" in q.describe()
