"""Fault-injection harness + elastic recovery + retry-path regressions.

Covers ISSUE 6's acceptance scenario end to end: under a seeded chaos
schedule (step kill, snapshot-shard corruption, preemption) training
resumes from the checkpoint tier with a loss curve bit-identical to the
uninterrupted run at the same seed; the stage-loss + replan + reshard
path runs under 2 host devices (tests/multidev/elastic.py).
"""
import os
import tempfile

import numpy as np
import pytest

from conftest import run_multidev
from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig, TrainConfig
from repro.configs.base import CheckpointPlan, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.train.chaos import (ChaosMonkey, ChaosSchedule, StageLostError,
                               TransientCollectiveError)
from repro.train.checkpoint import _flatten
from repro.train.fault import FaultHandler, retry_step
from repro.train.loop import make_manager, train

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


# ---------------------------------------------------------------------------
# schedule
def test_schedule_parse_roundtrip():
    spec = "kill@3:2,corrupt@5,stage_loss@7:1,preempt@9"
    sched = ChaosSchedule.parse(spec)
    assert [e.kind for e in sched.events] == \
        ["kill", "corrupt", "stage_loss", "preempt"]
    assert sched.events[0].arg == 2
    assert sched.events[1].arg == -1
    assert sched.spec() == "kill@3:2,corrupt@5,stage_loss@7:1,preempt@9"


def test_schedule_parse_rejects_garbage():
    with pytest.raises(ValueError, match="unknown chaos kind"):
        ChaosSchedule.parse("explode@3")
    with pytest.raises(ValueError, match="bad chaos event"):
        ChaosSchedule.parse("kill@three")


def test_schedule_random_is_seeded():
    a = ChaosSchedule.random(7, 200)
    b = ChaosSchedule.random(7, 200)
    c = ChaosSchedule.random(8, 200)
    assert a.spec() == b.spec()
    assert a.spec() != c.spec()
    assert len(a.events) > 0


# ---------------------------------------------------------------------------
# monkey hooks
def test_wrap_step_kills_then_passes_through():
    chaos = ChaosMonkey(ChaosSchedule.parse("kill@2:2"))
    calls = []

    def step(state, batch):
        calls.append(1)
        return state + 1, {}

    assert chaos.wrap_step(step, 0) is step          # unarmed step: no wrap
    wrapped = chaos.wrap_step(step, 2)
    for _ in range(2):
        with pytest.raises(TransientCollectiveError):
            wrapped(0, None)
    assert wrapped(0, None)[0] == 1                  # third attempt runs
    assert calls == [1]
    assert chaos.fired == ["kill@2", "kill@2"]


def test_before_step_stage_loss_and_preempt():
    chaos = ChaosMonkey(ChaosSchedule.parse("stage_loss@4:1,preempt@6"))
    chaos.before_step(3)                             # nothing scheduled
    with pytest.raises(StageLostError) as e:
        chaos.before_step(4)
    assert e.value.stage == 1
    chaos.before_step(4)                             # fired once only
    fh = FaultHandler(install_signals=False)
    chaos.before_step(6, fh)
    assert fh.should_stop
    assert chaos.fired == ["stage_loss@4", "preempt@6"]


def test_after_save_flips_a_shard_byte(tmp_path):
    d = tmp_path / "step_00000002"
    d.mkdir()
    blob = bytes(range(256))
    (d / "arrays.npz").write_bytes(blob)
    (d / "arrays.1.npz").write_bytes(blob)
    chaos = ChaosMonkey(ChaosSchedule.parse("corrupt@1:1"), seed=3)
    chaos.after_save(2, str(d))                      # event step 1 <= 2: due
    assert (d / "arrays.npz").read_bytes() == blob   # arg pins shard 1
    assert (d / "arrays.1.npz").read_bytes() != blob
    assert chaos.fired == ["corrupt@2:arrays.1.npz"]
    chaos.after_save(3, str(d))                      # one-shot
    assert len(chaos.fired) == 1


# ---------------------------------------------------------------------------
# retry_step regression (the terminal-backoff bug)
def test_retry_step_no_sleep_after_final_failure():
    sleeps = []

    def boom(state, batch):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError, match="always"):
        retry_step(boom, None, None, retries=3, backoff=0.5,
                   sleep=sleeps.append)
    # exponential backoff between attempts, but NO sleep after the last
    # failed attempt before raising
    assert sleeps == [0.5, 1.0, 2.0]


def test_retry_step_sleeps_only_between_failures():
    sleeps = []
    attempts = []

    def flaky(state, batch):
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, None, None, retries=4, backoff=0.25,
                      sleep=sleeps.append) == "ok"
    assert sleeps == [0.25, 0.5]


# ---------------------------------------------------------------------------
# end-to-end: seeded chaos run resumes bit-identical (acceptance criterion)
def _build(tc):
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 4, "train"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"), train=tc)
    return build_model(run)


def test_chaos_run_resumes_bit_identical():
    curves = {}

    def hooks(tag):
        curves[tag] = []
        return {"on_log": lambda s, m: curves[tag].append((s, m["loss"]))}

    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-2,
                         checkpoint_every=100, log_every=1, checkpoint_dir=d)
        ref_state, _ = train(_build(tc),
                             tc, iter(SyntheticLM(CFG, batch=4, seq=64,
                                                  seed=0)),
                             hooks=hooks("ref"))

    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-2,
                         checkpoint_every=2, log_every=1, checkpoint_dir=d)
        ckpt = CheckpointPlan(enabled=True, tier="host", codec="none",
                              shards=2, async_saves=True)
        chaos = ChaosMonkey(ChaosSchedule.parse("kill@3:2,corrupt@4,preempt@7"),
                            retries=2, backoff=0.0)
        m = _build(tc)
        mgr = make_manager(m, tc, ckpt, chaos)
        train(m, tc, iter(SyntheticLM(CFG, batch=4, seq=64, seed=0)),
              fault_handler=FaultHandler(install_signals=False),
              ckpt=ckpt, chaos=chaos, mgr=mgr, hooks=hooks("part1"))
        fired = ",".join(chaos.fired)
        assert "kill@3" in fired and "corrupt@" in fired \
            and "preempt@7" in fired, fired
        tr = mgr.runtime.traffic_report()
        assert tr["ckpt_save"]["wire_bytes"] > 0

        # simulated process restart: fresh model + manager, restore from disk
        m2 = _build(tc)
        mgr2 = make_manager(m2, tc, ckpt, None)
        state2, _ = train(m2, tc, iter(SyntheticLM(CFG, batch=4, seq=64,
                                                   seed=0)),
                          fault_handler=FaultHandler(install_signals=False),
                          ckpt=ckpt, mgr=mgr2, hooks=hooks("part2"))
        assert mgr2.runtime.traffic_report()["ckpt_load"]["wire_bytes"] > 0

    ref = dict(curves["ref"])
    for s, l in curves["part1"] + curves["part2"]:
        assert ref[s] == l, (s, l, ref[s])          # bit-identical curve
    for k, leaf in _flatten(ref_state).items():
        if leaf is not None:
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.asarray(_flatten(state2)[k]),
                                          err_msg=k)


def test_elastic_stage_loss():
    out = run_multidev("elastic.py", devices=2, timeout=900)
    assert "elastic stage-loss recovery OK" in out
