"""Checkpoints (atomic, keep-K, bf16 round-trip, reshard-on-load) + data
pipeline determinism/resume + fault handling."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import MemmapTokens, Prefetcher, SyntheticLM
from repro.train.checkpoint import CheckpointManager, to_device
from repro.train.fault import FaultHandler, StragglerMonitor, retry_step


def test_checkpoint_roundtrip_bf16_and_int8():
    state = {
        "params": {"w": jnp.ones((4, 8), jnp.bfloat16) * 1.5,
                   "b": jnp.arange(8, dtype=jnp.float32)},
        "opt": {"m": {"q": jnp.ones((4, 8), jnp.int8),
                      "scale": jnp.ones((4, 1), jnp.float32)},
                "count": jnp.int32(7)},
        "step": jnp.int32(7),
    }
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(7, {"state": state, "data": {"step": 7, "seed": 0}})
        step, payload = mgr.restore_latest()
        assert step == 7
        assert payload["data"]["step"] == 7
        template = jax.eval_shape(lambda: state)
        restored = to_device(payload["state"], template)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_keep_k_gc():
    state = {"params": {"w": jnp.zeros((2,))}, "step": jnp.int32(0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"state": state})
        assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial_dirs():
    state = {"params": {"w": jnp.zeros((2,))}, "step": jnp.int32(0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(5, {"state": state})
        assert not any(n.startswith("tmp.") for n in os.listdir(d))


# ---------------------------------------------------------------------------
def test_synthetic_determinism_and_resume():
    cfg = ARCHS["smollm-135m"].reduced()
    a = SyntheticLM(cfg, batch=2, seq=16, seed=3)
    b = SyntheticLM(cfg, batch=2, seq=16, seed=3)
    for t in (0, 5, 17):
        np.testing.assert_array_equal(a.batch_at(t)["tokens"],
                                      b.batch_at(t)["tokens"])
    # resume: restore state mid-stream
    it = iter(a)
    for _ in range(4):
        next(it)
    st = a.get_state()
    c = SyntheticLM(cfg, batch=2, seq=16, seed=99)
    c.set_state(st)
    t1, batch1 = next(iter(c))
    t2, batch2 = next(it)
    assert t1 == t2
    np.testing.assert_array_equal(batch1["tokens"], batch2["tokens"])


def test_labels_shift():
    cfg = ARCHS["smollm-135m"].reduced()
    b = SyntheticLM(cfg, batch=2, seq=16, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_memmap_tokens(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    cfg = ARCHS["smollm-135m"].reduced()
    ds = MemmapTokens(path, cfg, batch=2, seq=32, seed=0)
    t, b = next(iter(ds))
    np.testing.assert_array_equal(b["labels"], b["tokens"] + 1)


def test_prefetcher_wraps_and_closes():
    cfg = ARCHS["smollm-135m"].reduced()
    src = SyntheticLM(cfg, batch=2, seq=16, seed=1)
    pf = Prefetcher(src, depth=2)
    it = iter(pf)
    t0, b0 = next(it)
    t1, b1 = next(it)
    assert (t0, t1) == (0, 1)
    assert pf.get_state()["seed"] == 1
    pf.close()


# ---------------------------------------------------------------------------
def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0, window=20)
    for _ in range(15):
        assert not mon.observe(0.01)
    assert mon.observe(0.5)            # 50x median
    assert mon.flagged == 1


def test_retry_step_retries_then_succeeds():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("transient collective failure")
        return state + 1, {"ok": True}

    out, metrics = retry_step(flaky, 1, None, retries=2, backoff=0.0)
    assert out == 2 and calls["n"] == 2


def test_fault_handler_stop_flag():
    h = FaultHandler(install_signals=False)
    assert not h.should_stop
    h._handle(15, None)
    assert h.should_stop
