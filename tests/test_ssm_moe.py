"""SSD chunked-vs-recurrent equivalence (+hypothesis) and MoE vs dense-loop
reference on the local path (mesh paths run in tests/multidev)."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MemoryPlan, MeshPlan, ModelConfig
from repro.models.layers import ModelContext
from repro.models.moe import moe_block, moe_init
from repro.models.ssm import ssd_chunked, ssd_recurrent
from repro.parallel.sharding import ShardingPlanner


@hp.given(
    seed=st.integers(0, 100),
    S=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([8, 16, 32]),
    G=st.sampled_from([1, 2]),
)
@hp.settings(max_examples=25, deadline=None)
def test_ssd_chunked_equals_recurrent(seed, S, chunk, G):
    b, H, P, N = 2, 4, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (b, S, G, N)) * 0.3
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunk)
    y2, s2 = ssd_recurrent(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-4, atol=3e-4)


def test_ssd_state_continuation():
    """Splitting a sequence and passing the state must equal one pass —
    this is what makes chunked prefill + recurrent decode consistent."""
    b, S, H, P, G, N, c = 2, 64, 4, 8, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (b, S, G, N)) * 0.3
    y_full, s_full = ssd_chunked(x, dt, A, B, C, c)
    y_a, s_a = ssd_chunked(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], c)
    y_b, s_b = ssd_chunked(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:], c,
                           init_state=s_a)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_full),
        rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
def _dense_moe_ref(cfg, params, x):
    D = x.shape[-1]
    x2d = x.reshape(-1, D)
    probs = jax.nn.softmax(x2d.astype(jnp.float32) @ params["router"], -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2d)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x2d @ params["w1"][e]) * (x2d @ params["w3"][e])
        w_e = jnp.where(top_i == e, top_p, 0.0).sum(-1)
        out = out + (h @ params["w2"][e]) * w_e[:, None].astype(x2d.dtype)
    if cfg.shared_experts:
        h = jax.nn.silu(x2d @ params["shared_w1"]) * \
            (x2d @ params["shared_w3"])
        out = out + h @ params["shared_w2"]
    return out.reshape(x.shape)


def test_moe_local_equals_dense_loop():
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                      num_experts=4, top_k=2, shared_experts=1,
                      capacity_factor=2.0)
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5
    ctx = ModelContext(cfg=cfg, planner=ShardingPlanner(
        MeshPlan((1,), ("data",))), memory=MemoryPlan(), mesh=None)
    out, aux = moe_block(params, ctx, x)
    ref = _dense_moe_ref(cfg, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    assert 0.5 < float(aux) < 4.0      # load-balance loss near E*1/E*1 = 1


def test_moe_capacity_drops_overflow():
    """With capacity_factor << 1 tokens drop — output norm shrinks but stays
    finite (the drop path must not produce NaNs)."""
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                      num_experts=4, top_k=1, capacity_factor=0.25)
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    ctx = ModelContext(cfg=cfg, planner=ShardingPlanner(
        MeshPlan((1,), ("data",))), memory=MemoryPlan(), mesh=None)
    out, _ = moe_block(params, ctx, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    full_cfg = cfg.__class__(**{**cfg.__dict__, "capacity_factor": 4.0})
    out_full, _ = moe_block(params, ModelContext(
        cfg=full_cfg, planner=ShardingPlanner(MeshPlan((1,), ("data",))),
        memory=MemoryPlan(), mesh=None), x)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(out_full))
