"""Property-based serving invariants (hypothesis).

Random submit/requeue/cancel/retire traces against every scheduler policy
and random alloc/spill/fetch/free traces against the PageTable, reusing
the trace drivers from tests/test_paging.py (which also runs them on
seeded traces so the machinery is covered without hypothesis).

Invariants (the ISSUE's list):
* no session is lost or double-scheduled, for every policy;
* FCFS preserves arrival order of fresh (never-preempted) sessions;
* SRPT never runs a longer job while a shorter one waits;
* EDF never idles past an unmet deadline and always picks the earliest;
* pages are never aliased across sessions, the free list never
  double-frees, and metered transfers equal page_size x transfer count.

CI pins determinism via the "ci" profile registered in conftest.py
(HYPOTHESIS_PROFILE=ci: derandomized, fixed example budget).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from test_paging import (SCHED_NAMES, run_scheduler_trace,  # noqa: E402
                         run_table_trace)

# ---------------------------------------------------------------------------
# PageTable traces
table_ops = st.lists(
    st.tuples(st.sampled_from(["new", "grow", "pause", "resume", "free"]),
              st.integers(min_value=0, max_value=6)),
    max_size=200)


@given(ops=table_ops,
       num_pages=st.integers(min_value=1, max_value=10),
       page_size=st.sampled_from([1, 4, 16]))
@settings(max_examples=120, deadline=None)
def test_page_table_traces(ops, num_pages, page_size):
    table, state = run_table_trace(ops, num_pages=num_pages,
                                   page_size=page_size)
    # drain every survivor: the pool must come back whole
    for sid in list(state):
        for payload in table.free_session(sid):
            assert payload[0] == "page"
        table.check()
    assert table.num_free() + sum(
        1 for s in table.sessions() for e in table.entries(s)
        if e.resident) == table.num_pages


# ---------------------------------------------------------------------------
# scheduler traces
sched_ops = st.lists(
    st.tuples(st.sampled_from(["submit", "admit", "tick", "pause",
                               "retire", "cancel"]),
              st.integers(min_value=0, max_value=7),
              st.one_of(st.none(), st.integers(min_value=1, max_value=40))),
    max_size=150)


@pytest.mark.parametrize("name", SCHED_NAMES)
@given(ops=sched_ops, slots=st.integers(min_value=1, max_value=3))
@settings(max_examples=60, deadline=None)
def test_scheduler_traces(name, ops, slots):
    run_scheduler_trace(name, ops, slots=slots)


@given(ops=sched_ops)
@settings(max_examples=40, deadline=None)
def test_fair_scheduler_traces_with_quantum(ops):
    run_scheduler_trace("fair", ops, quantum=2)
