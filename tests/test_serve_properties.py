"""Property-based serving invariants (hypothesis).

Random submit/requeue/cancel/retire traces against every scheduler policy
and random alloc/spill/fetch/free traces against the PageTable, reusing
the trace drivers from tests/test_paging.py; plus the disaggregated-
serving drivers from tests/test_disagg.py (TransferQueue ordering and
the deadline-slack monotonicity sim).  Every driver also runs on seeded
traces in its home module, so the machinery is covered without
hypothesis.

Invariants (the ISSUEs' lists):
* no session is lost or double-scheduled, for every policy;
* FCFS preserves arrival order of fresh (never-preempted) sessions;
* SRPT never runs a longer job while a shorter one waits;
* EDF never idles past an unmet deadline and always picks the earliest;
* EDF misses are monotone (non-increasing) in uniform deadline slack;
* pages are never aliased across sessions, the free list never
  double-frees, and metered transfers equal page_size x transfer count;
* TransferQueue: pages FIFO per session, handoffs delivered exactly
  once, no starvation across sessions under backpressure requeues, and
  no payload leaked in the transfer tier.

CI pins determinism via the "ci" profile registered in conftest.py
(HYPOTHESIS_PROFILE=ci: derandomized, fixed example budget).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from test_disagg import (run_deadline_sim,                  # noqa: E402
                         run_transfer_queue_trace)
from test_paging import (SCHED_NAMES, run_scheduler_trace,  # noqa: E402
                         run_table_trace)

# ---------------------------------------------------------------------------
# PageTable traces — share/fork in the mix exercises the copy-on-write
# refcounts: shared holds, shared evictions (one payload for N holders)
# and re-homing refetches, with table.check() after every step
table_ops = st.lists(
    st.tuples(st.sampled_from(["new", "grow", "pause", "resume", "free",
                               "share", "fork"]),
              st.integers(min_value=0, max_value=6)),
    max_size=200)


@given(ops=table_ops,
       num_pages=st.integers(min_value=1, max_value=10),
       page_size=st.sampled_from([1, 4, 16]))
@settings(max_examples=120, deadline=None)
def test_page_table_traces(ops, num_pages, page_size):
    table, state = run_table_trace(ops, num_pages=num_pages,
                                   page_size=page_size)
    # drain every survivor: the pool must come back whole
    for sid in list(state):
        for payload in table.free_session(sid):
            assert payload[0] == "page"
        table.check()
    # conservation counts DISTINCT frames: a shared page backs many
    # entries but occupies one frame
    resident = {e.pid for s in table.sessions() for e in table.entries(s)
                if e.resident}
    assert table.num_free() + len(resident) == table.num_pages


# ---------------------------------------------------------------------------
# scheduler traces
sched_ops = st.lists(
    st.tuples(st.sampled_from(["submit", "admit", "tick", "pause",
                               "retire", "cancel"]),
              st.integers(min_value=0, max_value=7),
              st.one_of(st.none(), st.integers(min_value=1, max_value=40))),
    max_size=150)


@pytest.mark.parametrize("name", SCHED_NAMES)
@given(ops=sched_ops, slots=st.integers(min_value=1, max_value=3))
@settings(max_examples=60, deadline=None)
def test_scheduler_traces(name, ops, slots):
    run_scheduler_trace(name, ops, slots=slots)


@given(ops=sched_ops)
@settings(max_examples=40, deadline=None)
def test_fair_scheduler_traces_with_quantum(ops):
    run_scheduler_trace("fair", ops, quantum=2)


# ---------------------------------------------------------------------------
# TransferQueue traces (disaggregated prefill/decode handoffs)
queue_ops = st.lists(
    st.tuples(st.sampled_from(["publish", "adopt", "adopt", "cancel"]),
              st.integers(min_value=0, max_value=15)),
    max_size=120)


@given(ops=queue_ops,
       max_depth=st.one_of(st.none(), st.integers(min_value=1, max_value=6)))
@settings(max_examples=80, deadline=None)
def test_transfer_queue_traces(ops, max_depth):
    q, adopted = run_transfer_queue_trace(ops, max_depth=max_depth)
    assert q.depth() == 0                   # drained
    assert len(adopted) <= q.published      # delivered at most once each


# ---------------------------------------------------------------------------
# DeadlineScheduler: misses are monotone in uniform deadline slack.
# Adding the same slack to every real deadline preserves every EDF
# comparison (strict inequalities shift equally, seq tie-breaks are
# untouched), so the schedule — and each completion time — is identical;
# a request that meets its deadline at less slack must still meet it at
# more.  Staggered arrivals exercise the preempt/requeue path too.
jobs_strategy = st.lists(
    st.tuples(st.integers(min_value=0, max_value=12),    # arrival step
              st.integers(min_value=1, max_value=8),     # service tokens
              st.one_of(st.none(),
                        st.integers(min_value=1, max_value=30))),  # deadline
    min_size=1, max_size=12)


@given(jobs=jobs_strategy,
       slots=st.integers(min_value=1, max_value=3),
       slacks=st.tuples(st.integers(min_value=0, max_value=6),
                        st.integers(min_value=0, max_value=25)))
@settings(max_examples=80, deadline=None)
def test_deadline_misses_monotone_in_slack(jobs, slots, slacks):
    lo, hi = min(slacks), max(slacks)
    tight = run_deadline_sim(jobs, slots=slots, slack=lo)
    loose = run_deadline_sim(jobs, slots=slots, slack=hi)
    assert loose.misses <= tight.misses
    # the same requests were served either way; only the verdict moves
    assert tight.met + tight.misses == loose.met + loose.misses
    if lo == hi:
        assert (tight.met, tight.misses) == (loose.met, loose.misses)


# ---------------------------------------------------------------------------
# cluster router (drivers from tests/test_router.py): no session is ever
# placed on a non-ACTIVE engine (the SpyPolicy asserts on every choice),
# every drain terminates, and nothing is dropped — under arbitrary
# submit/drain/fail interleavings on every placement policy.
from test_router import (_assert_invariants, _make_wire_queue,  # noqa: E402
                         _run_ops)

router_ops = st.lists(
    st.tuples(st.sampled_from(["submit", "submit", "submit", "drain",
                               "fail"]),
              st.integers(min_value=0, max_value=31)),
    min_size=1, max_size=60)


@given(ops=router_ops,
       n_engines=st.integers(min_value=1, max_value=5),
       slots=st.integers(min_value=1, max_value=4),
       policy=st.sampled_from(["least_loaded", "round_robin",
                               "prefix_affinity"]))
@settings(max_examples=120, deadline=None)
def test_no_placement_on_draining_and_drain_terminates(
        ops, n_engines, slots, policy):
    _assert_invariants(_run_ops(ops, n_engines=n_engines, slots=slots,
                                policy=policy))


@given(ops=queue_ops,
       max_depth=st.one_of(st.none(), st.integers(min_value=1, max_value=6)))
@settings(max_examples=60, deadline=None)
def test_wire_queue_traces_match_loopback_invariants(ops, max_depth):
    """The byte-serialized wire transport driven through the SAME trace
    driver that pins the loopback TransferQueue: FIFO pages, exactly-once
    delivery, no starvation, no leaked payloads — now across frames."""
    q, adopted = run_transfer_queue_trace(
        ops, max_depth=max_depth, make_queue=_make_wire_queue)
    assert q.depth() == 0


# ---------------------------------------------------------------------------
# striped wire reassembly (driver from tests/test_wire_scaleout.py):
# random payload sizes x stripe counts x fragmented max_chunk reads x
# interleaved control frames must reproduce the single-stream byte
# stream — same message sequence, same pages — and the metering must
# reconcile exactly (sum of per-send returns == summed stripe bytes).
from repro.serve import transport as _tp                     # noqa: E402

from test_wire_scaleout import (msg_seqs_equal,              # noqa: E402
                                run_striped_reassembly_trace)

wire_msgs = st.lists(
    st.one_of(
        st.tuples(st.just("ctrl"),
                  st.sampled_from([_tp.K_ACK, _tp.K_CANCEL, _tp.K_RESULT]),
                  st.integers(min_value=0, max_value=1000)),
        st.tuples(st.just("handoff"),
                  st.lists(st.binary(min_size=0, max_size=2048),
                           max_size=5)),
    ),
    min_size=1, max_size=8)


@given(msgs=wire_msgs,
       streams=st.integers(min_value=1, max_value=5),
       max_chunk=st.one_of(st.none(),
                           st.integers(min_value=1, max_value=4096)))
@settings(max_examples=25, deadline=None)
def test_striped_reassembly_matches_single_stream(msgs, streams,
                                                  max_chunk):
    striped, single, s_meter, m_meter = run_striped_reassembly_trace(
        msgs, streams, max_chunk)
    assert msg_seqs_equal(striped, single)
    assert s_meter[0] == s_meter[1]
    assert m_meter[0] == m_meter[1]
