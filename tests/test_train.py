"""Training substrate: convergence, resume, 8-bit Adam, grad accumulation."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig, TrainConfig
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.train.fault import FaultHandler
from repro.train.loop import make_train_step, train
from repro.train.optimizer import (apply_adamw, init_opt_state, lr_schedule,
                                   opt_state_specs)
from repro.train.train_state import init_state

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


def _run(tc, memory=None, steps=None):
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 4, "train"),
                    mesh=PLAN1, memory=memory or MemoryPlan(policy="none"),
                    train=tc)
    m = build_model(run)
    data = SyntheticLM(CFG, batch=4, seq=64, seed=0)
    return train(m, tc, iter(data),
                 fault_handler=FaultHandler(install_signals=False))


def test_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(total_steps=30, warmup_steps=5, learning_rate=1e-2,
                         checkpoint_every=100, log_every=100,
                         checkpoint_dir=d)
        _, metrics = _run(tc)
        assert float(metrics["loss"]) < 6.0        # from ~6.3 at init


def test_resume_from_checkpoint_continues():
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-2,
                         checkpoint_every=10, log_every=100,
                         checkpoint_dir=d)
        _, m1 = _run(tc)
        tc2 = dataclasses.replace(tc, total_steps=20)
        _, m2 = _run(tc2)
        assert float(m2["loss"]) < float(m1["loss"]) + 0.05


def test_8bit_adam_tracks_fp32():
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        tc = TrainConfig(total_steps=25, warmup_steps=5, learning_rate=1e-2,
                         checkpoint_every=100, log_every=100,
                         checkpoint_dir=d1)
        _, m32 = _run(tc)
        tc8 = dataclasses.replace(tc, checkpoint_dir=d2)
        _, m8 = _run(tc8, memory=MemoryPlan(policy="none", opt_state_bits=8))
        assert abs(float(m8["loss"]) - float(m32["loss"])) < 0.15


def test_grad_accum_equivalence():
    """accum=2 over batch 8 must match accum=1 over the same batch (mean of
    microbatch grads == full-batch grad when token counts are equal)."""
    cfg = dataclasses.replace(CFG, dtype="float32")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"),
                    train=TrainConfig())
    m = build_model(run)
    tc1 = TrainConfig(grad_accum=1, grad_clip=0.0)
    tc2 = TrainConfig(grad_accum=2, grad_clip=0.0)
    B, S = 8, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
    }
    s1 = init_state(m, tc1)
    s2 = jax.tree.map(lambda x: x, s1)
    out1, _ = jax.jit(make_train_step(m, tc1))(s1, batch)
    out2, _ = jax.jit(make_train_step(m, tc2))(s2, batch)
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_grad_accum_metrics_accumulated():
    """Regression: the accumulated path used to hardcode aux_loss=0 and
    tokens=0, discarding per-microbatch metrics — it must now report the
    same token count and aux loss as the unaccumulated step."""
    cfg = dataclasses.replace(CFG, dtype="float32")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"),
                    train=TrainConfig())
    m = build_model(run)
    B, S = 8, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
    }
    tc1 = TrainConfig(grad_accum=1, grad_clip=0.0)
    tc2 = TrainConfig(grad_accum=2, grad_clip=0.0)
    _, m1 = jax.jit(make_train_step(m, tc1))(init_state(m, tc1), batch)
    _, m2 = jax.jit(make_train_step(m, tc2))(init_state(m, tc2), batch)
    assert float(m2["tokens"]) == float(m1["tokens"]) == B * S
    assert float(m2["aux_loss"]) == pytest.approx(float(m1["aux_loss"]),
                                                  abs=1e-5)
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-4)


def test_lr_schedule_shape():
    tc = TrainConfig(total_steps=100, warmup_steps=10, learning_rate=1e-3)
    lrs = [float(lr_schedule(tc, jnp.int32(s))) for s in (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]                 # warmup
    assert lrs[2] > lrs[3] > lrs[4]                 # cosine decay
    assert lrs[4] >= 0.09 * 1e-3                    # floor ~10%


def test_opt_state_specs_structure():
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    st = init_opt_state(params, bits=8)
    from jax.sharding import PartitionSpec as P
    specs = opt_state_specs({"w": P("data", "model"), "b": P(None)}, bits=8)
    assert set(specs["m"]["w"]) == {"q", "scale"}
    assert set(specs["v"]["w"]) == {"q", "lo", "hi"}
    assert jax.tree.structure(st["m"], is_leaf=lambda x: hasattr(x, "shape")) \
        is not None


def test_weight_decay_skips_scalars_and_clip():
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones(())}
    grads = {"w": jnp.full((4, 4), 100.0), "scale": jnp.zeros(())}
    st = init_opt_state(params)
    tc = TrainConfig(grad_clip=1.0, learning_rate=1e-2)
    new_p, new_st, metrics = apply_adamw(params, grads, st, tc)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))
    assert float(new_p["scale"]) == pytest.approx(1.0)   # zero grad, no decay
