"""MemoryTier / MemoryRuntime API: registry round-trips, tier composition,
traffic accounting, and gradient equivalence of wrapped vs plain layers on
the CPU backend."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MemoryPlan, MeshPlan
from repro.core.pool import PoolAccountant
from repro.core.runtime import MemoryRuntime
from repro.core.tiers import (CompressedTier, DeviceTier, HostTier,
                              PooledHbmTier, TransferHints, build_tier,
                              get_codec, registered_policies)
from repro.parallel.sharding import ShardingPlanner

SINGLE = MeshPlan((16, 16), ("data", "model"))
PLANNER = ShardingPlanner(SINGLE)


def _plans():
    """Every shipped MemoryPlan config combination the registry must serve."""
    plans = []
    for policy in ("none", "host", "mcdla", "auto", "spill"):
        for placement in ("bw_aware", "local"):
            for compress in ("none", "fp8", "int8"):
                plans.append(MemoryPlan(policy=policy, placement=placement,
                                        compress=compress))
    return plans


# ---------------------------------------------------------------------------
# registry round-trip
def test_registry_covers_all_shipped_policies():
    assert set(registered_policies()) == {"none", "host", "mcdla", "auto",
                                          "spill", "pipeline", "checkpoint"}


@pytest.mark.parametrize("memory", _plans(),
                         ids=lambda m: f"{m.policy}-{m.placement}-{m.compress}")
def test_tier_registry_roundtrip(memory):
    """Every shipped MemoryPlan resolves to a tier whose contract answers
    bandwidth and capacity, and whose stash/fetch round-trips a tensor."""
    memory.validate()
    tier = build_tier(memory, PLANNER)
    bw = tier.bandwidth(SINGLE)
    assert bw > 0
    acct = PoolAccountant(SINGLE, memory)
    assert tier.capacity(acct) > 0

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16), jnp.float32)
    hints = TransferHints(dtype=x.dtype)
    y = tier.fetch(tier.stash(x, hints), hints)
    tol = 0.1 if (memory.compress in ("fp8", "int8")
                  and tier.offloads) else 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=tol,
                               rtol=tol)


def test_unknown_policy_raises():
    with pytest.raises(KeyError):
        build_tier(dataclasses.replace(MemoryPlan(), policy="zram"), PLANNER)


def test_device_tier_does_not_offload():
    tier = build_tier(MemoryPlan(policy="none"), PLANNER)
    assert isinstance(tier, DeviceTier)
    assert not tier.offloads
    # compress on a non-offloading tier is a no-op stack
    tier_c = build_tier(MemoryPlan(policy="none", compress="fp8"), PLANNER)
    assert isinstance(tier_c, DeviceTier)


def test_stash_all_trait():
    assert build_tier(MemoryPlan(policy="mcdla"), PLANNER).stash_all
    assert build_tier(MemoryPlan(policy="host"), PLANNER).stash_all
    assert not build_tier(MemoryPlan(policy="auto"), PLANNER).stash_all


# ---------------------------------------------------------------------------
# composition: CompressedTier over HostTier
def test_compressed_host_composition():
    memory = MemoryPlan(policy="host", compress="fp8")
    tier = build_tier(memory, PLANNER)
    assert isinstance(tier, CompressedTier)
    assert isinstance(tier.inner, HostTier)
    assert tier.describe() == "host+fp8"
    assert tier.payload_ratio() == pytest.approx(0.5)
    # bandwidth contract comes from the host path, not the pool
    pooled = build_tier(MemoryPlan(policy="mcdla"), PLANNER)
    assert tier.bandwidth(SINGLE) < pooled.bandwidth(SINGLE)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16), jnp.float32)
    hints = TransferHints(dtype=jnp.float32)
    payload = tier.stash(x, hints)
    assert payload[1] is not None          # codec scale attached
    y = tier.fetch(payload, hints)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.06
    # allow_compress=False bypasses the codec (bit-exact round-trip)
    raw = tier.stash(x, TransferHints(dtype=jnp.float32,
                                      allow_compress=False))
    assert raw[1] is None
    np.testing.assert_array_equal(
        np.asarray(tier.fetch(raw, hints)), np.asarray(x))


def test_compressed_accounting_halves_pool_bytes():
    memory = MemoryPlan(policy="mcdla", compress="fp8")
    tier = build_tier(memory, PLANNER)
    acct = PoolAccountant(SINGLE, memory)
    tier.account(acct, 1e9)
    plain = build_tier(MemoryPlan(policy="mcdla"), PLANNER)
    acct2 = PoolAccountant(SINGLE, MemoryPlan(policy="mcdla"))
    plain.account(acct2, 1e9)
    assert acct.pooled_bytes == pytest.approx(0.5 * acct2.pooled_bytes)


def test_host_accounting_spares_hbm():
    memory = MemoryPlan(policy="host")
    tier = build_tier(memory, PLANNER)
    acct = PoolAccountant(SINGLE, memory)
    tier.account(acct, 1e9)
    assert acct.pooled_bytes == 0.0
    assert acct.local_bytes == 0.0
    # per-device share of the global stash, like the other acct fields
    assert acct.host_bytes == pytest.approx(1e9 / 256)


def test_device_accounting_is_per_device():
    memory = MemoryPlan(policy="none")
    tier = build_tier(memory, PLANNER)
    acct = PoolAccountant(SINGLE, memory)
    tier.account(acct, 1e9)           # global bytes, batch-sharded
    assert acct.local_bytes == pytest.approx(1e9 / 256)


def test_wire_ratio_skips_uncompressible():
    tier = build_tier(MemoryPlan(policy="mcdla", compress="fp8"), PLANNER)
    xf = jnp.ones((4, 4), jnp.float32)
    xi = jnp.ones((4, 4), jnp.int32)
    assert tier.wire_ratio(xf, TransferHints()) == pytest.approx(0.5)
    assert tier.wire_ratio(xf, TransferHints(allow_compress=False)) == 1.0
    assert tier.wire_ratio(xi, TransferHints()) == 1.0


def test_codec_registry():
    fp8 = get_codec("fp8")
    assert fp8.ratio == pytest.approx(0.5)
    with pytest.raises(KeyError):
        get_codec("zstd")


def test_int8_codec_roundtrip():
    int8 = get_codec("int8")
    assert int8.ratio == pytest.approx(0.5)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 32), jnp.float32)
    q, scale = int8.compress(x)
    assert q.dtype == jnp.int8
    y = int8.decompress(q, scale, jnp.float32)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.02                  # per-tensor int8: <2% relative error


def test_compressed_int8_tier_composition():
    tier = build_tier(MemoryPlan(policy="mcdla", compress="int8"), PLANNER)
    assert isinstance(tier, CompressedTier)
    assert tier.describe() == "pooled_hbm[bw_aware]+int8"
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 16), jnp.float32)
    hints = TransferHints(dtype=jnp.float32)
    y = tier.fetch(tier.stash(x, hints), hints)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.02


# ---------------------------------------------------------------------------
# SpillTier: primary until the capacity contract is spent, then overflow
def test_spill_tier_routes_primary_then_overflow():
    from repro.core.tiers import SpillTier
    memory = MemoryPlan(policy="spill")
    primary = PooledHbmTier(PLANNER, None, memory)
    overflow = HostTier(PLANNER, None, memory)
    x = jnp.ones((4, 8), jnp.float32)              # 128 bytes
    tier = SpillTier(primary, overflow, primary_budget=300.0)
    hints = TransferHints(dtype=jnp.float32)

    p1 = tier.stash(x, hints)
    p2 = tier.stash(x, hints)
    p3 = tier.stash(x, hints)                      # 384 > 300: overflows
    assert tier.leg_for(p1) == "primary"
    assert tier.leg_for(p2) == "primary"
    assert tier.leg_for(p3) == "overflow"
    for p in (p1, p2, p3):
        np.testing.assert_array_equal(np.asarray(tier.fetch(p, hints)),
                                      np.asarray(x))
    # discard returns primary budget: the next stash goes primary again
    tier.discard(p1)
    assert tier.leg_for(tier.stash(x, hints)) == "primary"


def test_spill_tier_prices_both_legs():
    from repro.core.tiers import SpillTier
    memory = MemoryPlan(policy="spill")
    tier = build_tier(memory, PLANNER)
    assert isinstance(tier, SpillTier)
    assert tier.describe() == "spill[pooled_hbm[bw_aware]->host]"
    acct = PoolAccountant(SINGLE, memory)
    # capacity: both legs (pool + host DRAM)
    pooled = build_tier(MemoryPlan(policy="mcdla"), PLANNER)
    host = build_tier(MemoryPlan(policy="host"), PLANNER)
    assert tier.capacity(acct) == pytest.approx(
        pooled.capacity(acct) + host.capacity(acct))
    # bandwidth: the primary leg while it has headroom, degraded toward
    # the host leg once the budget is spent
    assert tier.bandwidth(SINGLE) == pytest.approx(pooled.bandwidth(SINGLE))
    small = SpillTier(PooledHbmTier(PLANNER, None, memory),
                      HostTier(PLANNER, None, memory), primary_budget=64.0)
    small.stash(jnp.ones((16, 16), jnp.float32),
                TransferHints(dtype=jnp.float32))  # overflows immediately
    small.stash(jnp.ones((16, 16), jnp.float32),
                TransferHints(dtype=jnp.float32))
    assert small.bandwidth(SINGLE) < pooled.bandwidth(SINGLE)
    assert small.bandwidth(SINGLE) > 0.0


def test_spill_payload_survives_pytree():
    """The leg routing is static treedef data: jit residuals keep it."""
    from repro.core.tiers import SpillPayload
    p = SpillPayload("overflow", 128.0, (jnp.ones((2, 2)), None))
    leaves, treedef = jax.tree_util.tree_flatten(p)
    q = jax.tree_util.tree_unflatten(treedef, leaves)
    assert q.leg == "overflow" and q.nbytes == 128.0
    np.testing.assert_array_equal(np.asarray(q.inner[0]), np.ones((2, 2)))


# ---------------------------------------------------------------------------
# bandwidth contract ordering (paper Fig. 10 / §IV)
def test_bandwidth_contract_orders():
    bw_aware = build_tier(MemoryPlan(policy="mcdla", placement="bw_aware"),
                          PLANNER)
    local = build_tier(MemoryPlan(policy="mcdla", placement="local"), PLANNER)
    host = build_tier(MemoryPlan(policy="host"), PLANNER)
    assert bw_aware.bandwidth(SINGLE) >= local.bandwidth(SINGLE)
    assert local.bandwidth(SINGLE) > host.bandwidth(SINGLE)


def test_pooled_capacity_exceeds_device():
    memory = MemoryPlan(policy="mcdla")
    pooled = build_tier(memory, PLANNER)
    device = build_tier(MemoryPlan(policy="none"), PLANNER)
    acct = PoolAccountant(SINGLE, memory)
    assert pooled.capacity(acct) == pytest.approx(acct.budget * 256)
    assert device.capacity(acct) == pytest.approx(acct.budget)


# ---------------------------------------------------------------------------
# gradient equivalence of wrapped vs plain layers, per tier, CPU backend
def _layer(params, x, pos):
    h = jnp.einsum("bsd,df->bsf", x, params["w1"])
    h = jax.nn.silu(h) + pos.astype(h.dtype)[None, :, None] * 0.0
    return x + jnp.einsum("bsf,fd->bsd", h, params["w2"])


def _setup():
    key = jax.random.PRNGKey(0)
    B, S, D, F = 4, 8, 16, 32
    params = {"w1": jax.random.normal(key, (D, F)) * 0.1,
              "w2": jax.random.normal(jax.random.PRNGKey(2), (F, D)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    return params, x, pos


@pytest.mark.parametrize("memory", [
    MemoryPlan(policy="none"),
    MemoryPlan(policy="mcdla"),
    MemoryPlan(policy="mcdla", placement="local"),
    MemoryPlan(policy="auto"),
    MemoryPlan(policy="host"),
], ids=lambda m: f"{m.policy}-{m.placement}")
def test_wrapped_gradients_match_plain(memory):
    params, x, pos = _setup()
    runtime = MemoryRuntime(SINGLE, memory)
    wrapped = runtime.wrap_layer(_layer, compute_spec=None)

    def loss(fn, p, xx):
        return jnp.sum(fn(p, xx, pos) ** 2)

    v = loss(wrapped, params, x)
    vref = loss(_layer, params, x)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vref), rtol=1e-5)
    g = jax.grad(lambda p, xx: loss(wrapped, p, xx), argnums=(0, 1))(params, x)
    gref = jax.grad(lambda p, xx: loss(_layer, p, xx), argnums=(0, 1))(params, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_wrapped_gradients_fp8_close():
    params, x, pos = _setup()
    runtime = MemoryRuntime(SINGLE, MemoryPlan(policy="mcdla", compress="fp8"))
    wrapped = runtime.wrap_layer(_layer, compute_spec=None)
    g = jax.grad(lambda p, xx: jnp.sum(wrapped(p, xx, pos) ** 2),
                 argnums=(0, 1))(params, x)
    gref = jax.grad(lambda p, xx: jnp.sum(_layer(p, xx, pos) ** 2),
                    argnums=(0, 1))(params, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gref)):
        a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
        cos = (a @ b) / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12)
        assert cos > 0.99


def test_aux_fetch_derives_own_layout():
    """Aux tensors whose rank differs from the residual must not inherit a
    static residual compute_spec (the old code crashed / mis-constrained)."""
    from jax.sharding import PartitionSpec as P

    params, x, pos = _setup()
    enc = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 2, 8))  # rank 4

    def layer_with_aux(p, xx, enc_states):
        mixed = xx + jnp.mean(enc_states, axis=2) @ jnp.eye(
            enc_states.shape[-1], xx.shape[-1], dtype=xx.dtype)
        return _layer(p, mixed, jnp.arange(xx.shape[1], dtype=jnp.int32))

    runtime = MemoryRuntime(SINGLE, MemoryPlan(policy="mcdla"))
    # static rank-3 residual spec; aux is rank 4 — must derive its own
    wrapped = runtime.wrap_layer(layer_with_aux,
                                 compute_spec=P("data", None, None))
    g = jax.grad(lambda p, xx, e: jnp.sum(wrapped(p, xx, e) ** 2),
                 argnums=(0, 1, 2))(params, x, enc)
    gref = jax.grad(lambda p, xx, e: jnp.sum(layer_with_aux(p, xx, e) ** 2),
                    argnums=(0, 1, 2))(params, x, enc)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# runtime facade
def test_runtime_traffic_report():
    params, x, pos = _setup()
    runtime = MemoryRuntime(SINGLE, MemoryPlan(policy="mcdla"))
    wrapped = runtime.wrap_layer(_layer, compute_spec=None)
    jax.grad(lambda p, xx: jnp.sum(wrapped(p, xx, pos) ** 2))(params, x)
    rep = runtime.traffic_report()
    raw = float(x.size) * x.dtype.itemsize
    assert rep["tier"] == "pooled_hbm[bw_aware]"
    assert rep["stash"]["raw_bytes"] == pytest.approx(raw)
    assert rep["fetch"]["raw_bytes"] == pytest.approx(raw)
    assert rep["est_transfer_s"] > 0
    runtime.reset_traffic()
    assert runtime.traffic_report()["wire_bytes_total"] == 0.0
    assert "tier=" in runtime.traffic_summary()


def test_runtime_no_offload_is_identity():
    runtime = MemoryRuntime(SINGLE, MemoryPlan(policy="none"))
    assert runtime.wrap_layer(_layer) is _layer
    assert runtime.resolve_stash_groups(None, None, 12) == 0


def test_runtime_resolves_stash_groups():
    from repro.configs import SHAPES_BY_NAME, get_arch

    cfg = get_arch("smollm-135m")
    shape = SHAPES_BY_NAME["train_4k"]
    mc = MemoryRuntime(SINGLE, MemoryPlan(policy="mcdla"))
    assert mc.resolve_stash_groups(cfg, shape, cfg.num_layers) == \
        cfg.num_layers
    auto = MemoryRuntime(SINGLE, MemoryPlan(policy="auto"))
    k = auto.resolve_stash_groups(cfg, shape, cfg.num_layers)
    assert 0 <= k <= cfg.num_layers
