"""Shared fixtures.  NOTE: XLA device-count flags are NOT set here — smoke
tests and benches see 1 device; multi-device tests run via subprocess
(tests/multidev/)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

try:
    # deterministic hypothesis profile for CI (HYPOTHESIS_PROFILE=ci):
    # derandomized with a fixed example budget so the property suite gives
    # the same verdict on every run of the same tree.  Loaded explicitly —
    # registering alone does nothing, and not every hypothesis version
    # honors the env var by itself.
    from hypothesis import settings as _hyp_settings
    _hyp_settings.register_profile(
        "ci", derandomize=True, max_examples=60, deadline=None,
        print_blob=True)
    if os.environ.get("HYPOTHESIS_PROFILE"):
        _hyp_settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ImportError:                     # property tests importorskip anyway
    pass


def run_multidev(script: str, devices: int = 8, timeout: int = 600):
    """Run tests/multidev/<script> in a child python with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    path = os.path.join(REPO, "tests", "multidev", script)
    proc = subprocess.run([sys.executable, path], env=env, timeout=timeout,
                          capture_output=True, text=True)
    assert proc.returncode == 0, (
        f"{script} failed\n--- stdout ---\n{proc.stdout[-4000:]}"
        f"\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
