"""The paper's claims, validated against our simulator (EXPERIMENTS.md §Paper
anchors).  Exact magnitudes depend on unpublished simulator internals; the
assertions pin the orderings and the headline bands."""
import pytest

from repro import hw
from repro.sim.power import DIMM_OPTIONS, perf_per_watt, system_overhead, table4
from repro.sim.simulator import harmonic_mean, simulate, speedup_table
from repro.sim.topology import (ALL_SYSTEMS, DC_DLA, DC_DLA_GEN4, DC_DLA_O,
                                HC_DLA, MC_DLA_B, MC_DLA_L, MC_DLA_S)
from repro.sim.workloads import CNNS, RNNS, WORKLOADS


@pytest.fixture(scope="module")
def dags():
    return {k: f() for k, f in WORKLOADS.items()}


@pytest.fixture(scope="module")
def tables(dags):
    return {mode: speedup_table(dags, ALL_SYSTEMS, mode)
            for mode in ("dp", "mp")}


def _hm(tab, name):
    return harmonic_mean([tab[w][name] for w in tab])


def test_workload_layer_counts(dags):
    # Table III layer counts
    assert dags["AlexNet"].num_layers == 8
    assert dags["GoogLeNet"].num_layers == 58
    assert dags["VGG-E"].num_layers == 19
    assert dags["ResNet"].num_layers == 34
    assert dags["RNN-GEMV"].num_layers == 50
    assert dags["RNN-GRU"].num_layers == 187


def test_system_ordering_every_workload(tables):
    """DC <= HC,MC(S) <= MC(L) <= MC(B) <= oracle for every workload/mode."""
    for mode, tab in tables.items():
        for w, row in tab.items():
            assert row["MC-DLA(B)"] >= row["MC-DLA(L)"] - 1e-6, (mode, w)
            assert row["MC-DLA(L)"] >= row["MC-DLA(S)"] - 1e-6, (mode, w)
            assert row["DC-DLA(O)"] >= row["MC-DLA(B)"] - 1e-6, (mode, w)
            # sync-dominated RNN MP cells may dip slightly below 1.0: our
            # latency model charges the 16-node MC rings ~7% more than the
            # paper's (documented deviation, EXPERIMENTS.md §Paper anchors)
            floor = 0.9 if (mode == "mp" and w.startswith("RNN")) else 1.0
            assert row["MC-DLA(B)"] >= floor, (mode, w)


def test_headline_speedup_band(tables):
    """Paper: MC-DLA(B) 3.5x dp / 2.1x mp / 2.8x overall vs DC-DLA."""
    dp = _hm(tables["dp"], "MC-DLA(B)")
    mp = _hm(tables["mp"], "MC-DLA(B)")
    overall = harmonic_mean([dp, mp])
    assert 3.0 <= dp <= 5.0, dp
    assert 1.4 <= mp <= 2.8, mp
    assert 2.0 <= overall <= 3.6, overall


def test_oracle_fraction(tables):
    """Paper: MC-DLA(B) reaches 84-99% (avg 95%) of the oracle."""
    for mode in ("dp", "mp"):
        frac = _hm(tables[mode], "MC-DLA(B)") / _hm(tables[mode], "DC-DLA(O)")
        assert 0.80 <= frac <= 1.0, (mode, frac)


def test_local_close_to_bw_aware(tables):
    """Paper: MC-DLA(L) achieves ~96% of MC-DLA(B)."""
    for mode in ("dp", "mp"):
        r = _hm(tables[mode], "MC-DLA(L)") / _hm(tables[mode], "MC-DLA(B)")
        assert 0.88 <= r <= 1.0, (mode, r)


def test_hc_between_dc_and_mc(tables):
    for mode in ("dp", "mp"):
        hc = _hm(tables[mode], "HC-DLA")
        assert 1.0 <= hc <= _hm(tables[mode], "MC-DLA(B)")


def test_cpu_bandwidth_usage(dags):
    """Paper Fig 12: HC-DLA consumes a large share of CPU memory bandwidth
    (avg 92% cited); MC uses none."""
    fracs = []
    for w, dag in dags.items():
        r = simulate(dag, HC_DLA, "dp")
        fracs.append(r.cpu_bw_frac)
        assert simulate(dag, MC_DLA_B, "dp").cpu_bw_frac == 0.0
    assert max(fracs) > 0.5


def test_pcie_gen4_narrows_gap(dags):
    """Paper §V-B: PCIe gen4 improves DC-DLA ~38%, narrowing MC/DC to ~2.1x."""
    base, gen4 = [], []
    for w, dag in dags.items():
        base.append(simulate(dag, DC_DLA, "dp").total)
        gen4.append(simulate(dag, DC_DLA_GEN4, "dp").total)
    gain = harmonic_mean([b / g for b, g in zip(base, gen4)])
    assert 1.15 <= gain <= 2.2, gain


def test_batch_sensitivity_robust(dags):
    """Paper Fig 14: MC-DLA(B) keeps a healthy speedup across batch sizes."""
    from repro.sim.workloads import WORKLOADS as W
    for batch in (128, 256, 1024):
        sp = []
        for name, fn in W.items():
            dag = fn(batch)
            sp.append(simulate(dag, DC_DLA, "dp").total
                      / simulate(dag, MC_DLA_B, "dp").total)
        assert harmonic_mean(sp) > 1.5, batch


def test_scalability_4_vs_8(dags):
    """Paper §V-D: with virtualization ON, DC-DLA scales poorly (1.3x/2.7x
    at 4/8 devices); MC-DLA regains near-linear scaling."""
    dag = dags["VGG-E"]
    t1_dc = simulate(dag, DC_DLA, "dp", n_devices=1).total
    t8_dc = simulate(dag, DC_DLA, "dp", n_devices=8).total
    t1_mc = simulate(dag, MC_DLA_B, "dp", n_devices=1).total
    t8_mc = simulate(dag, MC_DLA_B, "dp", n_devices=8).total
    assert (t1_mc / t8_mc) > (t1_dc / t8_dc)
    assert (t1_mc / t8_mc) > 5.0          # near-linear for MC
    # virtualization off -> both near-linear
    t1 = simulate(dag, DC_DLA, "dp", n_devices=1, virtualize=False).total
    t8 = simulate(dag, DC_DLA, "dp", n_devices=8, virtualize=False).total
    assert (t1 / t8) > 6.0


def test_breakdown_categories(dags):
    """Fig 11: DC-DLA is virtualization-dominated on most workloads; the
    MC designs cut virtualization without inflating sync."""
    worse = 0
    for w, dag in dags.items():
        dc = simulate(dag, DC_DLA, "dp")
        mc = simulate(dag, MC_DLA_B, "dp")
        if dc.virt > dc.compute:
            worse += 1
        assert mc.virt < dc.virt
        assert mc.sync <= dc.sync * 1.6      # longer rings cost a little
    assert worse >= 5         # paper: 14/16 cases virtualization-bound


def test_power_table4():
    t = table4()
    assert t["8GB RDIMM"]["node_tdp_w"] == pytest.approx(29.0)
    assert t["128GB LRDIMM"]["gb_per_w"] == pytest.approx(10.1, abs=0.1)
    ov_small = system_overhead(DIMM_OPTIONS[0])
    ov_big = system_overhead(DIMM_OPTIONS[-1])
    assert ov_small["power_increase_frac"] == pytest.approx(0.0725, abs=0.01)
    assert ov_big["power_increase_frac"] == pytest.approx(0.3175, abs=0.01)
    assert ov_big["pool_capacity_tb"] == pytest.approx(10.24, abs=0.1)
    # paper: 2.1-2.6x perf/W for a 2.8x speedup
    assert 2.0 <= perf_per_watt(2.8, DIMM_OPTIONS[0]) <= 2.7
    assert 2.0 <= perf_per_watt(2.8, DIMM_OPTIONS[-1]) <= 2.3
