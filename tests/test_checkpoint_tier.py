"""Checkpoint-as-a-tier: CheckpointTier stack, metered snapshot runtime,
cadence planning, and the sharded/atomic/async CheckpointManager.

The manifest accounts the same raw/wire bytes the ``ckpt_save`` /
``ckpt_load`` meters count, so every test closes the loop against disk
truth; crash-mid-save and corruption paths pin the atomicity guarantees
the chaos harness (tests/test_chaos.py) relies on.
"""
import glob
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CheckpointPlan, MemoryPlan, MeshPlan
from repro import hw
from repro.core.policy import (CADENCE_CANDIDATES, plan_checkpoint,
                               plan_memory, summarize)
from repro.core.tiers import (CheckpointTier, HostTier, build_ckpt_tier,
                              registered_policies)
from repro.parallel.sharding import ShardingPlanner
from repro.train.checkpoint import (CheckpointError, CheckpointManager,
                                    make_ckpt_runtime)

PLAN1 = MeshPlan((1,), ("data",))
MEM = MemoryPlan()


def _runtime(ckpt=None, keep=1):
    ckpt = ckpt or CheckpointPlan(enabled=True, tier="host", codec="none")
    return make_ckpt_runtime(ckpt, PLAN1, MEM, keep=keep)


def _state():
    return {
        "params": {"w": jnp.arange(64 * 32,
                                   dtype=jnp.float32).reshape(64, 32) / 7,
                   "b": jnp.ones((32,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 32), jnp.float32)},
        "step": jnp.array(3, jnp.int32),
    }


# ---------------------------------------------------------------------------
# tier layer
def test_checkpoint_policy_registered():
    assert "checkpoint" in registered_policies()


def test_ckpt_tier_offloads_and_describes():
    tier = build_ckpt_tier(MEM, ShardingPlanner(PLAN1), backing="host")
    assert isinstance(tier, CheckpointTier)
    assert tier.offloads
    assert tier.describe() == "ckpt[host]"
    codec = build_ckpt_tier(MEM, ShardingPlanner(PLAN1), backing="host",
                            codec="fp8")
    assert "ckpt[host]" in codec.describe() and "fp8" in codec.describe()


def test_ckpt_tier_bandwidth_is_series_with_dcn():
    tier = build_ckpt_tier(MEM, ShardingPlanner(PLAN1), backing="host")
    inner_bw = tier.inner.bandwidth(PLAN1, hw.TPU_V5E)
    bw = tier.bandwidth(PLAN1, hw.TPU_V5E)
    assert 0 < bw <= min(inner_bw, hw.DCN_BW)   # series resistance


def test_ckpt_tier_capacity_scales_with_keep():
    planner = ShardingPlanner(PLAN1)
    t1 = build_ckpt_tier(MEM, planner, backing="host", keep=1)
    t4 = build_ckpt_tier(MEM, planner, backing="host", keep=4)

    class FakeAcct:
        pass
    acct = FakeAcct()
    c1 = t1.capacity(acct) if hasattr(t1.inner, "capacity") else 0
    c4 = t4.capacity(acct)
    if c1 > 0:
        assert c4 == pytest.approx(c1 / 4)


def test_snapshot_metering_matches_payload_bytes():
    from repro.core.tiers import TransferHints
    rt = _runtime(CheckpointPlan(enabled=True, tier="host", codec="fp8"))
    x = jnp.ones((128, 64), jnp.float32)
    hints = TransferHints(dtype=jnp.dtype(jnp.float32), name="w")
    payload = rt.snapshot(x, hints)
    back = rt.restore_snapshot(payload, hints)
    assert back.shape == x.shape and back.dtype == x.dtype
    tr = rt.traffic_report()
    raw = 128 * 64 * 4
    assert tr["ckpt_save"]["raw_bytes"] == raw
    # fp8 payload + f32 scales — actual bytes, not the analytic ratio
    wire = sum(float(np.asarray(jax.device_get(p)).nbytes)
               for p in payload if p is not None)
    assert tr["ckpt_save"]["wire_bytes"] == wire
    assert tr["ckpt_load"]["wire_bytes"] == wire
    assert tr["ckpt_load"]["raw_bytes"] == raw


# ---------------------------------------------------------------------------
# cadence planner
def test_plan_checkpoint_explicit_cadence():
    tier = build_ckpt_tier(MEM, ShardingPlanner(PLAN1), backing="host")
    dec = plan_checkpoint(1e9, 0.1, tier, PLAN1, every=25)
    assert dec.every == 25
    assert dec.snapshot_bytes == pytest.approx(1e9 * tier.payload_ratio())
    assert dec.save_s > 0 and dec.total_s > 0


def test_plan_checkpoint_sweeps_young_daly():
    tier = build_ckpt_tier(MEM, ShardingPlanner(PLAN1), backing="host")
    dec = plan_checkpoint(1e9, 0.1, tier, PLAN1, mtbf_steps=1000)
    assert dec.every in CADENCE_CANDIDATES
    # sweep must beat (or match) both extremes of the grid
    lo = plan_checkpoint(1e9, 0.1, tier, PLAN1, every=CADENCE_CANDIDATES[0],
                         mtbf_steps=1000)
    hi = plan_checkpoint(1e9, 0.1, tier, PLAN1, every=CADENCE_CANDIDATES[-1],
                         mtbf_steps=1000)
    assert dec.total_s <= lo.total_s + 1e-12
    assert dec.total_s <= hi.total_s + 1e-12


def test_plan_checkpoint_async_hides_save():
    tier = build_ckpt_tier(MEM, ShardingPlanner(PLAN1), backing="host")
    sync = plan_checkpoint(1e9, 0.5, tier, PLAN1, every=10)
    asyn = plan_checkpoint(1e9, 0.5, tier, PLAN1, every=10, async_saves=True)
    assert asyn.overhead_s <= sync.overhead_s
    assert asyn.async_saves and not sync.async_saves


def test_plan_memory_attaches_checkpoint_decision():
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.core.dag import build_dag
    cfg = ARCHS["smollm-135m"].reduced()
    dag = build_dag(cfg, ShapeConfig("t", 64, 4, "train"))
    report = plan_memory(dag, PLAN1, MEM,
                         model_state_bytes=cfg.param_count() * 16.0,
                         checkpoint=CheckpointPlan(enabled=True, tier="host",
                                                   mtbf_steps=500))
    assert report.checkpoint is not None
    assert report.checkpoint.every >= 1
    assert report.checkpoint.snapshot_bytes > 0
    assert "ckpt[" in summarize(report)


# ---------------------------------------------------------------------------
# manager: roundtrip / shards / async / metering == manifest
@pytest.mark.parametrize("codec,exact", [("none", True), ("fp8", False),
                                         ("int8", False)])
def test_manager_roundtrip_codecs(codec, exact):
    with tempfile.TemporaryDirectory() as d:
        rt = _runtime(CheckpointPlan(enabled=True, tier="host", codec=codec))
        mgr = CheckpointManager(d, keep=2, runtime=rt)
        state = _state()
        mgr.save(7, {"state": state, "data": {"step": 7, "seed": 0}})
        step, payload = mgr.restore_latest()
        assert step == 7
        assert payload["data"] == {"step": 7, "seed": 0}
        got = payload["state"]["params::w"]
        want = np.asarray(state["params"]["w"])
        if exact:
            np.testing.assert_array_equal(got, want)
        else:
            # quantization floor: half an int8 step at the tensor's max
            atol = float(np.max(np.abs(want))) / 127 + 1e-6
            np.testing.assert_allclose(got, want, rtol=0.1, atol=atol)
        man = json.load(open(os.path.join(d, "step_00000007",
                                          "manifest.json")))
        tr = rt.traffic_report()
        assert tr["ckpt_save"]["wire_bytes"] == man["bytes"]["wire"]
        assert tr["ckpt_load"]["wire_bytes"] == man["bytes"]["wire"]
        if codec != "none":
            assert man["bytes"]["wire"] < man["bytes"]["raw"]


def test_manager_shards_balanced_and_all_read():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=1, runtime=_runtime(), shards=3)
        mgr.save(1, {"state": _state(), "data": None})
        files = sorted(os.path.basename(p) for p in
                       glob.glob(os.path.join(d, "step_00000001", "*.npz")))
        assert files == ["arrays.1.npz", "arrays.2.npz", "arrays.npz"]
        man = json.load(open(os.path.join(d, "step_00000001",
                                          "manifest.json")))
        assert len(man["shards"]) == 3
        assert {e["shard"] for e in man["keys"]} <= {0, 1, 2}
        step, payload = mgr.restore_latest()
        np.testing.assert_array_equal(payload["state"]["params::w"],
                                      np.asarray(_state()["params"]["w"]))


def test_manager_async_save_overlaps_and_waits():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, runtime=_runtime(),
                                async_saves=True)
        mgr.save(1, {"state": _state(), "data": None})
        mgr.save(2, {"state": _state(), "data": None})  # waits for save 1
        mgr.wait()
        assert mgr.all_steps() == [1, 2]
        step, _ = mgr.restore_latest()
        assert step == 2


def test_manager_async_failure_surfaces_in_wait(monkeypatch):
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, runtime=_runtime(),
                                async_saves=True)
        monkeypatch.setattr(
            "repro.train.checkpoint.os.replace",
            lambda *a: (_ for _ in ()).throw(OSError("disk gone")))
        mgr.save(1, {"state": _state(), "data": None})
        with pytest.raises(OSError, match="disk gone"):
            mgr.wait()


def test_legacy_manager_reads_tierless():
    # no runtime: direct write, and a manifest without "shards" (legacy
    # layout) still restores
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(5, {"state": _state(), "data": None})
        man_path = os.path.join(d, "step_00000005", "manifest.json")
        man = json.load(open(man_path))
        del man["shards"]
        json.dump(man, open(man_path, "w"))
        step, payload = mgr.restore_latest()
        assert step == 5
        np.testing.assert_array_equal(payload["state"]["params::w"],
                                      np.asarray(_state()["params"]["w"]))


# ---------------------------------------------------------------------------
# corruption handling (restore raises, restore_latest skips + warns)
def _two_checkpoints(d, mgr=None):
    mgr = mgr or CheckpointManager(d, keep=3, runtime=_runtime(), shards=2)
    mgr.save(1, {"state": _state(), "data": None})
    mgr.save(2, {"state": _state(), "data": None})
    return mgr


def test_restore_raises_on_corrupt_shard():
    with tempfile.TemporaryDirectory() as d:
        mgr = _two_checkpoints(d)
        f = os.path.join(d, "step_00000002", "arrays.1.npz")
        with open(f, "r+b") as fh:
            fh.seek(40)
            b = fh.read(1)
            fh.seek(40)
            fh.write(bytes([b[0] ^ 0xFF]))
        with pytest.raises(CheckpointError, match="CRC mismatch"):
            mgr.restore(2)
        step, _ = mgr.restore_latest()          # skips + warns, falls back
        assert step == 1


def test_restore_raises_on_missing_shard():
    with tempfile.TemporaryDirectory() as d:
        mgr = _two_checkpoints(d)
        os.remove(os.path.join(d, "step_00000002", "arrays.npz"))
        with pytest.raises(CheckpointError, match="arrays.npz missing"):
            mgr.restore(2)
        assert mgr.restore_latest()[0] == 1


def test_restore_raises_on_bad_manifest():
    with tempfile.TemporaryDirectory() as d:
        mgr = _two_checkpoints(d)
        mpath = os.path.join(d, "step_00000002", "manifest.json")
        with open(mpath, "w") as f:
            f.write("{not json")
        with pytest.raises(CheckpointError, match="manifest.json unreadable"):
            mgr.restore(2)
        os.remove(mpath)
        with pytest.raises(CheckpointError, match="manifest.json missing"):
            mgr.restore(2)
        assert mgr.restore_latest()[0] == 1


def test_restore_raises_on_missing_step():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, runtime=_runtime())
        with pytest.raises(CheckpointError, match="no such checkpoint"):
            mgr.restore(42)
        assert mgr.restore_latest() is None


# ---------------------------------------------------------------------------
# crash-mid-save atomicity
def test_crash_between_write_and_commit_preserves_previous(monkeypatch):
    with tempfile.TemporaryDirectory() as d:
        mgr = _two_checkpoints(d)
        # crash injected between the arrays/manifest writes and os.replace:
        # the commit never happens, step_3 must not exist
        monkeypatch.setattr(
            "repro.train.checkpoint.os.replace",
            lambda *a: (_ for _ in ()).throw(OSError("power cut")))
        with pytest.raises(OSError, match="power cut"):
            mgr.save(3, {"state": _state(), "data": None})
        monkeypatch.undo()
        assert mgr.all_steps() == [1, 2]
        assert mgr.restore_latest()[0] == 2     # previous step intact
        orphans = glob.glob(os.path.join(d, "tmp.*"))
        assert orphans                           # the wreck is on disk...
        mgr.save(4, {"state": _state(), "data": None})
        assert not glob.glob(os.path.join(d, "tmp.*"))   # ...swept next save
        assert mgr.restore_latest()[0] == 4


def test_keep_k_garbage_collection():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, runtime=_runtime())
        for s in (1, 2, 3, 4):
            mgr.save(s, {"state": _state(), "data": None})
        assert mgr.all_steps() == [3, 4]
