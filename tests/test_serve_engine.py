"""Batched serving engine: greedy decode correctness + slot isolation +
pooled-cache sizing math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import kv_cache_footprint

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _greedy_ref(m, params, prompt, n_new):
    """Reference: repeated full prefill (no cache reuse)."""
    toks = list(prompt)
    for _ in range(n_new):
        t = jnp.asarray(toks, jnp.int32)[None, :]
        pos = jnp.arange(len(toks), dtype=jnp.int32)[None, :]
        caches = m.init_cache(1, len(toks) + 1)
        logits, _ = m.prefill(params, {"tokens": t, "positions": pos}, caches)
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


def test_engine_matches_full_forward(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64)
    prompt = np.arange(7, dtype=np.int32) + 3
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    done = eng.run()
    assert len(done) == 1
    want = _greedy_ref(m, params, list(prompt), 6)
    assert done[0].out_tokens == want


def test_engine_batched_slots_isolated(model_and_params):
    """Two concurrent sequences must decode exactly what they decode alone."""
    m, params = model_and_params
    p1 = np.arange(5, dtype=np.int32) + 1
    p2 = (np.arange(9, dtype=np.int32) * 3 + 2) % CFG.vocab_size
    solo = []
    for p in (p1, p2):
        eng = Engine(m, params, batch=2, max_len=64)
        eng.submit(Request(uid=0, prompt=p, max_new_tokens=5))
        solo.append(eng.run()[0].out_tokens)
    eng = Engine(m, params, batch=2, max_len=64)
    eng.submit(Request(uid=0, prompt=p1, max_new_tokens=5))
    eng.submit(Request(uid=1, prompt=p2, max_new_tokens=5))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert done[0].out_tokens == solo[0]
    assert done[1].out_tokens == solo[1]


def test_engine_queues_beyond_slots(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert sorted(r.uid for r in done) == list(range(5))


# ---------------------------------------------------------------------------
def test_kv_footprint_long_context_needs_pool():
    """zamba2 @ 524k decode: the KV cache exceeds one chip's HBM but fits
    pooled (the paper's capacity argument applied to inference)."""
    from repro import hw
    from repro.configs import SINGLE_POD, get_arch
    fp = kv_cache_footprint(get_arch("zamba2-2.7b"), SINGLE_POD,
                            batch=1, seq=524_288)
    assert fp.per_device_unpooled > hw.TPU_V5E.hbm_bytes
    assert fp.per_device_pooled < hw.TPU_V5E.hbm_bytes


def test_kv_footprint_ssm_tiny():
    from repro.configs import SINGLE_POD, get_arch
    fp = kv_cache_footprint(get_arch("mamba2-370m"), SINGLE_POD,
                            batch=1, seq=524_288)
    assert fp.total_bytes < 1e9         # O(1) state: no long-context blowup
