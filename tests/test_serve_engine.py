"""Batched serving engine: greedy decode correctness + slot isolation +
pooled-cache sizing math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import kv_cache_footprint

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _greedy_ref(m, params, prompt, n_new):
    """Reference: repeated full prefill (no cache reuse)."""
    toks = list(prompt)
    for _ in range(n_new):
        t = jnp.asarray(toks, jnp.int32)[None, :]
        pos = jnp.arange(len(toks), dtype=jnp.int32)[None, :]
        caches = m.init_cache(1, len(toks) + 1)
        logits, _ = m.prefill(params, {"tokens": t, "positions": pos}, caches)
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


def test_engine_matches_full_forward(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64)
    prompt = np.arange(7, dtype=np.int32) + 3
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    done = eng.run()
    assert len(done) == 1
    want = _greedy_ref(m, params, list(prompt), 6)
    assert done[0].out_tokens == want


def test_engine_batched_slots_isolated(model_and_params):
    """Two concurrent sequences must decode exactly what they decode alone."""
    m, params = model_and_params
    p1 = np.arange(5, dtype=np.int32) + 1
    p2 = (np.arange(9, dtype=np.int32) * 3 + 2) % CFG.vocab_size
    solo = []
    for p in (p1, p2):
        eng = Engine(m, params, batch=2, max_len=64)
        eng.submit(Request(uid=0, prompt=p, max_new_tokens=5))
        solo.append(eng.run()[0].out_tokens)
    eng = Engine(m, params, batch=2, max_len=64)
    eng.submit(Request(uid=0, prompt=p1, max_new_tokens=5))
    eng.submit(Request(uid=1, prompt=p2, max_new_tokens=5))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert done[0].out_tokens == solo[0]
    assert done[1].out_tokens == solo[1]


def test_engine_queues_beyond_slots(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert sorted(r.uid for r in done) == list(range(5))


# ---------------------------------------------------------------------------
def test_kv_footprint_long_context_needs_pool():
    """zamba2 @ 524k decode: the KV cache exceeds one chip's HBM but fits
    pooled (the paper's capacity argument applied to inference)."""
    from repro import hw
    from repro.configs import SINGLE_POD, get_arch
    fp = kv_cache_footprint(get_arch("zamba2-2.7b"), SINGLE_POD,
                            batch=1, seq=524_288)
    assert fp.per_device_unpooled > hw.TPU_V5E.hbm_bytes
    assert fp.per_device_pooled < hw.TPU_V5E.hbm_bytes


def test_kv_footprint_ssm_tiny():
    from repro.configs import SINGLE_POD, get_arch
    fp = kv_cache_footprint(get_arch("mamba2-370m"), SINGLE_POD,
                            batch=1, seq=524_288)
    assert fp.total_bytes < 1e9         # O(1) state: no long-context blowup


# ---------------------------------------------------------------------------
# Scheduler / KVCacheManager / Session stack
def _solo_tokens(m, params, prompt, n_new):
    eng = Engine(m, params, batch=1, max_len=64)
    eng.submit(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                       max_new_tokens=n_new))
    return eng.run()[0].out_tokens


def test_mixed_length_decode_groups(model_and_params):
    """Three concurrent prompts of different lengths: the per-length decode
    groups must not cross-contaminate each other's cache rows."""
    m, params = model_and_params
    prompts = [np.arange(3, dtype=np.int32) + 1,
               np.arange(5, dtype=np.int32) + 2,
               (np.arange(9, dtype=np.int32) * 5 + 1) % CFG.vocab_size]
    solo = [_solo_tokens(m, params, p, 5) for p in prompts]
    eng = Engine(m, params, batch=3, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert [r.out_tokens for r in done] == solo


def test_slot_retire_readmit_reuse(model_and_params):
    """A retired slot's cache rows are reused by the next admission without
    leaking the previous occupant's KV."""
    m, params = model_and_params
    short = np.arange(4, dtype=np.int32) + 1
    long_ = (np.arange(6, dtype=np.int32) * 7 + 2) % CFG.vocab_size
    solo_long = _solo_tokens(m, params, long_, 4)
    eng = Engine(m, params, batch=1, max_len=64)
    eng.submit(Request(uid=0, prompt=short, max_new_tokens=2))
    s1 = eng.submit(Request(uid=1, prompt=long_, max_new_tokens=4))
    done = eng.run()
    assert [r.uid for r in done] == [0, 1]
    assert s1.result() == solo_long
    # both sessions decoded through the same (only) slot
    assert len(eng.cache.slots) == 1 and eng.cache.slots[0] is None


def test_spill_roundtrip_cold_slot(model_and_params):
    """Acceptance: more requests than slots completes with cold slots
    spilled to the secondary tier (asserted via traffic_report()), and the
    spill/fetch round-trip preserves every sequence's greedy decode."""
    m, params = model_and_params
    from repro.serve.scheduler import FairScheduler
    prompts = [((np.arange(4 + i, dtype=np.int32) * (i + 2) + 1)
                % CFG.vocab_size) for i in range(5)]
    solo = [_solo_tokens(m, params, p, 6) for p in prompts]
    eng = Engine(m, params, batch=2, max_len=64,
                 scheduler=FairScheduler(quantum=2))
    sessions = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
                for i, p in enumerate(prompts)]
    done = eng.run()
    assert len(done) == 5
    assert [s.result() for s in sessions] == solo
    assert all(s.finish_reason == "length" for s in sessions)
    # at least one session was actually paused and resumed
    assert sum(s.preemptions for s in sessions) > 0
    report = eng.traffic_report()
    assert report["kv_stash"]["calls"] > 0
    assert report["kv_fetch"]["calls"] > 0
    assert report["kv_stash"]["wire_bytes"] > 0
    # everything parked in the spill tier was drained back
    assert eng.cache.spilled_uids() == []


def test_spill_overflow_leg_roundtrip(model_and_params):
    """With a tiny primary budget the cold slots overflow to host DRAM —
    decode results must be identical (the overflow leg is bit-exact)."""
    m, params = model_and_params
    from repro.configs.base import MemoryPlan
    from repro.core.runtime import MemoryRuntime
    from repro.core.tiers import SpillTier, build_tier
    from repro.serve.scheduler import FairScheduler
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(4)]
    solo = [_solo_tokens(m, params, p, 5) for p in prompts]
    spill_rt = MemoryRuntime(m.plan, MemoryPlan(policy="spill"),
                             planner=m.planner)
    assert isinstance(spill_rt.tier, SpillTier)
    spill_rt.tier.primary_budget = 1.0          # force the overflow leg
    eng = Engine(m, params, batch=2, max_len=64,
                 scheduler=FairScheduler(quantum=2), spill=spill_rt)
    sessions = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
                for i, p in enumerate(prompts)]
    eng.run()
    assert [s.result() for s in sessions] == solo
    assert sum(s.preemptions for s in sessions) > 0


def test_auto_sized_engine_from_tier_report(model_and_params):
    """Acceptance: Engine constructed without batch/max_len sizes itself
    from the tier report."""
    m, params = model_and_params
    eng = Engine(m, params)             # no batch / max_len
    assert eng.cache.auto_sized
    assert eng.batch >= 1 and eng.max_len >= 16
    # the sizing honours the tier's capacity contract: the resident cache
    # fits inside the budget fraction it was given
    from repro.serve.kv_cache import DEFAULT_HBM_FRAC, kv_cache_footprint
    total = kv_cache_footprint(m.cfg, m.plan, eng.batch, eng.max_len).total_bytes
    assert total <= DEFAULT_HBM_FRAC * eng.kv_report["capacity_bytes"]
    # and it still serves correctly
    p = np.arange(5, dtype=np.int32) + 1
    sess = eng.submit(Request(uid=0, prompt=p, max_new_tokens=4))
    eng.run()
    assert sess.result() == _solo_tokens(m, params, p, 4)


def test_auto_size_respects_caps(model_and_params):
    m, _ = model_and_params
    from repro.serve.kv_cache import derive_cache_shape
    sized = derive_cache_shape(m.cfg, m.runtime, None, None,
                               max_batch=3, default_max_len=128)
    assert sized["batch"] <= 3 and sized["max_len"] <= 128
    assert sized["report"]["capacity_bytes"] > 0
    # explicit sizes pass through untouched
    fixed = derive_cache_shape(m.cfg, m.runtime, 2, 64)
    assert fixed["batch"] == 2 and fixed["max_len"] == 64


def test_session_streaming_and_states(model_and_params):
    m, params = model_and_params
    from repro.serve.session import SessionState
    streamed = []
    eng = Engine(m, params, batch=1, max_len=64)
    sess = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 2,
                              max_new_tokens=3),
                      on_token=lambda s, t: streamed.append(t))
    assert sess.state is SessionState.QUEUED
    eng.run()
    assert sess.state is SessionState.FINISHED
    assert sess.finish_reason == "length"
    assert streamed == sess.result() and len(streamed) == 3
    # legacy alias: Request.out_tokens is the same stream
    assert sess.request.out_tokens == streamed


def test_last_cache_row_not_wasted(model_and_params):
    """Off-by-one fix: a slot decodes until length == max_len (the old
    `length + 1 >= max_len` retired one row early)."""
    m, params = model_and_params
    max_len = 16
    prompt = np.arange(4, dtype=np.int32) + 1
    eng = Engine(m, params, batch=1, max_len=max_len)
    sess = eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=100))
    eng.run()
    assert sess.finish_reason == "cache_full"
    # prefill cached 4 rows; decode fills ALL remaining rows
    assert sess.length == max_len
    assert len(sess.result()) == max_len - len(prompt) + 1


def test_priority_scheduler_preempts(model_and_params):
    m, params = model_and_params
    prompts = {0: np.arange(4, dtype=np.int32) + 1,
               1: np.arange(5, dtype=np.int32) + 3,
               2: np.arange(6, dtype=np.int32) + 5}
    solo = {u: _solo_tokens(m, params, p, 5) for u, p in prompts.items()}
    eng = Engine(m, params, batch=1, max_len=64, scheduler="priority")
    low = eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=5,
                             priority=0))
    eng.step()                          # low-priority session is resident
    hi = eng.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=5,
                            priority=5))
    mid = eng.submit(Request(uid=2, prompt=prompts[2], max_new_tokens=5,
                             priority=1))
    done = eng.run()
    # the high-priority request preempted and finished first
    assert [r.uid for r in done] == [1, 2, 0]
    assert low.preemptions >= 1
    for sess, uid in ((low, 0), (hi, 1), (mid, 2)):
        assert sess.result() == solo[uid]


def test_scheduler_registry():
    from repro.serve.scheduler import build_scheduler, registered_schedulers
    assert set(registered_schedulers()) == {"fcfs", "priority", "fair",
                                            "srpt", "deadline"}
    assert build_scheduler("fair", quantum=4).quantum == 4
    assert build_scheduler("srpt").name == "srpt"
    assert build_scheduler("deadline").misses == 0
    with pytest.raises(KeyError):
        build_scheduler("lifo")


def test_session_cancel_running_and_paused(model_and_params):
    """cancel() stops a resident session's decode (no tokens after the
    cancelling callback) and drops a paused session's parked cache,
    returning its SpillTier budget instead of leaking it."""
    m, params = model_and_params
    from repro.serve.scheduler import FairScheduler
    from repro.serve.session import SessionState

    # cancel mid-stream from the on_token callback
    eng = Engine(m, params, batch=1, max_len=64)
    sess = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                              max_new_tokens=10),
                      on_token=lambda s, t: s.cancel() if len(s.tokens) >= 3
                      else None)
    done = eng.run()
    assert sess.state is SessionState.CANCELLED
    assert sess.finish_reason == "cancelled"
    assert len(sess.result()) == 3           # nothing emitted after cancel
    assert done == []                        # cancelled != finished
    assert eng.cache.slots == [None]

    # cancel while paused: the spilled entry is swept and budget returned
    eng = Engine(m, params, batch=1, max_len=64,
                 scheduler=FairScheduler(quantum=1))
    s0 = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                            max_new_tokens=8))
    s1 = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 2,
                            max_new_tokens=8))
    eng.step()                               # s0 resident
    eng.step()                               # s0 paused (quantum), s1 in
    assert s0.state is SessionState.PAUSED
    assert eng.cache.spilled_uids() == [0]
    s0.cancel()
    eng.run()
    assert eng.cache.spilled_uids() == []    # swept, not leaked
    assert s0.state is SessionState.CANCELLED
    assert s1.state is SessionState.FINISHED
    assert len(s1.result()) == 8


def test_prompt_too_long_rejected(model_and_params):
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=8)
    sess = eng.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                              max_new_tokens=4))
    eng.run()
    assert sess.finish_reason == "rejected"
    assert sess.result() == []


# ---------------------------------------------------------------------------
# in-place paged decode (decode_kernel=True): the kernel path must be a
# drop-in — same token streams, no per-step gather — and compressed cold
# pages must serve through the fused in-kernel dequant
def _drive_streams(m, params, reqs, **kw):
    eng = Engine(m, params, **kw)
    for uid, prompt, n in reqs:
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=n))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return [r.out_tokens for r in done], eng


def test_paged_kernel_stream_identity(model_and_params):
    """Kernel on vs off over mixed-length concurrent sessions: identical
    token streams.  The xla impl is pinned so the comparison checks the
    serving-path wiring (in-place page writes, block-table routing,
    scratch masking) bit-for-bit; the Pallas kernel's own numerics are
    pinned by the parity sweep in test_kernels.py."""
    from repro.kernels import ops
    m, params = model_and_params
    reqs = [(0, np.arange(5, dtype=np.int32) + 1, 6),
            (1, (np.arange(9, dtype=np.int32) * 3 + 2) % CFG.vocab_size, 6),
            (2, np.arange(11, dtype=np.int32) % CFG.vocab_size, 4)]
    kw = dict(batch=2, max_len=64, page_size=8)
    off, _ = _drive_streams(m, params, reqs, decode_kernel=False, **kw)
    ops.set_paged_impl("xla")
    try:
        on, eng = _drive_streams(m, params, reqs, decode_kernel=True, **kw)
    finally:
        ops.set_paged_impl("pallas")
    assert off == on
    io = eng.traffic_report()["decode_io"]
    assert io["in_place"] and io["steps"] > 0
    # the metered read scales with pages held, not pool size
    assert 0 < io["pages_touched"] < io["pages_gather_equiv"]
    assert io["bytes_touched"] < io["bytes_gather_equiv"]


def test_paged_kernel_pallas_streams_finite(model_and_params):
    """The Pallas impl end-to-end: streams may differ from the gather
    path by argmax near-ties (reduction-order ULPs) but must be complete
    and the engine state must stay healthy."""
    m, params = model_and_params
    reqs = [(0, np.arange(6, dtype=np.int32) + 2, 5),
            (1, np.arange(4, dtype=np.int32) + 9, 5)]
    streams, eng = _drive_streams(m, params, reqs, batch=2, max_len=64,
                                  page_size=8, decode_kernel=True)
    assert [len(s) for s in streams] == [5, 5]
    assert all(0 <= t < CFG.vocab_size for s in streams for t in s)


def test_paged_kernel_compressed_pages_stream_identity(model_and_params):
    """Eviction under an overcommitted pool with an int8 codec, then
    resume: cold pages re-enter as *compressed* residents (int8 side
    pool) and decode through the fused in-kernel dequant — the streams
    must match the kernel-off engine, which inflates the same pages
    through decode_tensor on resume (identical dequant math)."""
    from repro.kernels import ops
    from repro.serve.quota import TenantQuota
    from repro.serve.scheduler import FairScheduler

    m, params = model_and_params
    rng = np.random.default_rng(5)
    reqs = [(i, rng.integers(0, CFG.vocab_size, size=(10,)).astype(np.int32),
             10) for i in range(4)]
    kw = dict(batch=2, max_len=32, page_size=4, pages=10, spill="host",
              quota=TenantQuota(codec="int8"))
    off, _ = _drive_streams(m, params, reqs,
                            scheduler=FairScheduler(quantum=3),
                            decode_kernel=False, **kw)
    ops.set_paged_impl("xla")
    try:
        on, eng = _drive_streams(m, params, reqs,
                                 scheduler=FairScheduler(quantum=3),
                                 decode_kernel=True, **kw)
    finally:
        ops.set_paged_impl("pallas")
    assert off == on
    io = eng.traffic_report()["decode_io"]
    assert io["compressed_adopts"] > 0, \
        "workload never exercised compressed residency"


def test_decode_attention_inactive_slot_is_finite():
    """cache_index=-1 (a drained slot padding out a decode batch) masks
    every key; the fully-masked softmax row must stay finite, not NaN,
    or the masked-merge would smear NaN into live slots' caches."""
    from repro.models.attention import decode_attention
    B, S, K, d = 2, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, 4, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    out = decode_attention(q, k, v, jnp.int32(-1))
    assert bool(jnp.all(jnp.isfinite(out)))
    # windowed variant exercises the second mask term
    out_w = decode_attention(q, k, v, jnp.int32(-1), window=4)
    assert bool(jnp.all(jnp.isfinite(out_w)))


def test_prefix_prefill_attention_padded_rows_are_finite():
    """positions=-1 pad rows (ragged prefill) have no causal keys; every
    logit in those rows is masked and the output must stay finite."""
    from repro.models.attention import prefix_prefill_attention
    B, S, K, d = 2, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, 4, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    pos = jnp.full((B, S), -1, jnp.int32)       # all rows are padding
    out = prefix_prefill_attention(q, k, v, pos)
    assert bool(jnp.all(jnp.isfinite(out)))
