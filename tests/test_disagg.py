"""Disaggregated prefill/decode: cross-role trace equivalence + handoff
invariants.

The acceptance bar (ISSUE 4): disaggregated decode token streams are
bit-identical to colocated paged decode for seeded traces, transfer bytes
are metered through ``traffic_report()`` (wire bytes == page bytes x
shipped pages), no page is lost or duplicated across the handoff, decode-
side backpressure parks pages in the transfer tier (never re-prefills),
and quota reservations follow the session to the decode side.

The trace drivers (`run_transfer_queue_trace` / `run_deadline_sim`) are
shared with the hypothesis property suite
(tests/test_serve_properties.py); here they run on seeded-random traces
so the machinery is exercised even when hypothesis is not installed.
"""
import random

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig
from repro.configs.base import ShapeConfig
from repro.models import transformer as tfm
from repro.models.model import build_model
from repro.serve.disagg import DisaggPair, KVHandoff, TransferQueue, \
    build_disagg
from repro.serve.engine import Engine, Request
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.scheduler import FairScheduler, build_scheduler
from repro.serve.session import Session, SessionState

from test_paging import _solo  # noqa: F401 — shared solo-decode reference

CFG = ARCHS["smollm-135m"].reduced()
PLAN1 = MeshPlan((1,), ("data",))


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _prompts(n, base=4):
    return [((np.arange(base + i, dtype=np.int32) * (i + 2) + 1)
             % CFG.vocab_size) for i in range(n)]


# ---------------------------------------------------------------------------
# the acceptance twin: disagg == colocated paged == solo, bit-identical
def test_disagg_streams_identical_to_colocated(model_and_params):
    m, params = model_and_params
    prompts = _prompts(5)
    want = [_solo(m, params, p, 6) for p in prompts]

    def colocated(**kw):
        eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                     spill="host", **kw)
        ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
              for i, p in enumerate(prompts)]
        eng.run()
        return [s.result() for s in ss]

    assert colocated() == want

    def disagg(**kw):
        pair = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                            transfer="host", spill="host", **kw)
        ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=6))
              for i, p in enumerate(prompts)]
        pair.run()
        return pair, [s.result() for s in ss]

    pair, got = disagg()
    assert got == want                          # plain FIFO decode
    pair2, got2 = disagg(pages=3,
                         decode_scheduler=FairScheduler(quantum=2))
    assert got2 == want                         # overcommit + preemption
    # the overcommitted run really moved pages through the spill tier on
    # top of the adoption traffic
    pages = pair2.decode.traffic_report()["pages"]
    assert pages["adoptions"] == 5
    assert pages["evictions"] > 0


def test_disagg_streams_with_staggered_retires(model_and_params):
    """Unequal max_new_tokens: decode slots retire and re-fill mid-run
    with adoptions crossing the handoff — streams still bit-identical."""
    m, params = model_and_params
    prompts = _prompts(4)
    new_tokens = [3, 9, 4, 6]
    want = [_solo(m, params, p, n) for p, n in zip(prompts, new_tokens)]
    pair = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=n))
          for i, (p, n) in enumerate(zip(prompts, new_tokens))]
    pair.run()
    assert [s.result() for s in ss] == want
    assert all(s.finish_reason == "length" for s in ss)


def test_publish_retry_does_not_duplicate_first_token(model_and_params):
    """Bugfix: a TransportError mid-publish requeues the session for a
    fresh prefill, which re-samples and re-emits the first token — the
    client-facing on_token stream used to see it twice."""
    from repro.serve.transport import TransportError
    m, params = model_and_params
    prompt = _prompts(1)[0]
    want = _solo(m, params, prompt, 5)
    pair = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    real_publish = pair.transfer.publish
    state = {"failed": False}

    def fail_once(*args, **kw):
        if not state["failed"]:
            state["failed"] = True
            raise TransportError("wire dropped mid-frame")
        return real_publish(*args, **kw)

    pair.transfer.publish = fail_once
    streamed = []
    s = pair.submit(Request(uid=0, prompt=prompt, max_new_tokens=5),
                    on_token=lambda sess, tok: streamed.append(tok))
    pair.run()
    assert state["failed"]                  # the wire really dropped once
    assert s.result() == want
    assert streamed == want                 # first token streamed ONCE


def test_disagg_transfer_bytes_metered(model_and_params):
    """Acceptance: transferred bytes == page bytes x shipped pages, on
    both legs (publish and adopt), with no page lost or duplicated."""
    m, params = model_and_params
    prompts = _prompts(4, base=18)              # 18..21 rows -> 2 pages each
    pair = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(prompts)]
    pair.run()
    assert all(s.finish_reason == "length" for s in ss)
    rep = pair.transfer.traffic_report()
    tq = rep["transfer"]
    assert tq["shipped_pages"] == 4 * 2         # ceil(18..21 / 16) == 2
    assert tq["adopted_pages"] == tq["shipped_pages"]   # none lost
    assert tq["published"] == tq["delivered"] - tq["requeued"] == 4
    # one page's bytes across the paged kv leaves
    page_leaves = jax.tree_util.tree_leaves(
        tfm.page_slice(pair.decode.cache.pool, 0))
    page_bytes = sum(x.size * x.dtype.itemsize for x in page_leaves)
    assert rep["kv_publish"]["wire_bytes"] == tq["shipped_pages"] * page_bytes
    assert rep["kv_adopt"]["wire_bytes"] == tq["shipped_pages"] * page_bytes
    assert rep["kv_publish"]["calls"] == \
        tq["shipped_pages"] * len(page_leaves)
    # every adoption claimed fresh frames exactly once, all freed at retire
    table = pair.decode.cache.table
    assert table.adoptions == 4
    assert table.sessions() == ()
    assert table.num_free() == table.num_pages


def test_disagg_backpressure_parks_pages_not_reprefill(model_and_params):
    """Decode-side pool pressure: the handoff requeues (to the BACK), its
    pages stay parked in the transfer tier, and the session is never
    prefilled again — prefill publishes exactly once per request."""
    m, params = model_and_params
    prompts = _prompts(3)
    # decode: 3 slots over a 2-page pool -> the third adoption finds every
    # frame hot (two running sessions pin one page each) and must requeue
    pair = build_disagg(m, params, batch=3, max_len=32, page_size=16,
                        pages=2, transfer="host", spill="host")
    ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=8))
          for i, p in enumerate(prompts)]
    pair.run()
    assert [len(s.result()) for s in ss] == [8, 8, 8]
    tq = pair.transfer
    assert tq.requeued > 0                      # backpressure happened
    assert tq.published == 3                    # ...but no re-prefill
    assert tq.shipped_pages == tq.adopted_pages == 3
    assert pair.decode.cache.table.adoptions == 3
    want = [_solo(m, params, p, 8) for p in prompts]
    assert [s.result() for s in ss] == want


def test_disagg_quota_reservation_follows_session(model_and_params):
    """The worst-case page reservation charged at prefill admission stays
    on the shared ledger while the KV is in flight and serializes the
    tenant across the role split, releasing only at decode-side retire."""
    m, params = model_and_params
    qm = QuotaManager({"A": TenantQuota(max_pages=2)})
    pair = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host", quota=qm)
    # 20 prompt + 10 new = 30 rows -> 2 pages each: A's 2nd must wait for
    # the 1st's reservation to come back from the DECODE side
    a = [pair.submit(Request(uid=i, prompt=np.arange(20, dtype=np.int32),
                             max_new_tokens=10, tenant="A"))
         for i in range(2)]
    b = pair.submit(Request(uid=5, prompt=np.arange(20, dtype=np.int32),
                            max_new_tokens=10, tenant="B"))
    pair.prefill.step()                         # a0 prefilled + published
    assert qm.charge_of(a[0].uid) == ("A", 2)   # charged...
    assert pair.transfer.depth() == 1           # ...while parked in transit
    assert qm.usage()["A"]["pages"] == 2
    pair.prefill.step()                         # A over budget: b admits past
    assert qm.charge_of(a[1].uid) is None
    assert qm.charge_of(b.uid) == ("B", 2)
    pair.run()
    assert all(s.finish_reason == "length" for s in a + [b])
    assert qm.charged_uids() == ()              # every reservation returned
    assert qm.usage()["A"] == {"sessions": 0, "pages": 0}


def test_disagg_cancel_in_transit_releases_everything(model_and_params):
    """Satellite fix: a session cancelled while its handoff is parked in
    the transfer queue must release its quota reservation and its parked
    page payloads (no re-prefill, no ledger leak)."""
    m, params = model_and_params
    qm = QuotaManager({"A": TenantQuota(max_pages=4)})
    pair = build_disagg(m, params, batch=1, max_len=64, page_size=16,
                        transfer="host", spill="host", quota=qm)
    s0 = pair.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                             max_new_tokens=6, tenant="A"))
    s1 = pair.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 2,
                             max_new_tokens=6, tenant="A"))
    pair.prefill.step()
    pair.step()                                 # s0 adopted; s1 published
    assert pair.transfer.depth() == 1           # s1 parked behind batch=1
    assert qm.charge_of(1) == ("A", 1)
    s1.cancel()
    pair.run()
    assert s0.result() == _solo(m, params, np.arange(4, dtype=np.int32) + 1, 6)
    assert s1.state is SessionState.CANCELLED
    assert len(s1.result()) == 1                # only the prefill token
    assert pair.transfer.swept == 1             # payloads dropped in place
    assert pair.transfer.depth() == 0
    assert qm.charged_uids() == ()              # reservation released
    assert qm.usage()["A"] == {"sessions": 0, "pages": 0}


def test_quota_cancel_while_deferred_releases_reservation(model_and_params):
    """Satellite regression (colocated twin): cancelling a session parked
    at admission — deferred on quota, or paused holding a charge — must
    leave the tenant ledger empty; deferral alone never holds a charge."""
    m, params = model_and_params
    qm = QuotaManager({"A": TenantQuota(max_sessions=1)})
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 spill="host", quota=qm)
    a0 = eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=6, tenant="A"))
    a1 = eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32),
                            max_new_tokens=6, tenant="A"))
    eng.step()                                  # a0 resident, a1 deferred
    assert qm.charge_of(0) == ("A", 1)
    assert qm.charge_of(1) is None              # deferred != charged
    a1.cancel()
    eng.step()
    assert qm.usage()["A"]["sessions"] == 1     # a0 only
    eng.run()
    assert a0.finish_reason == "length"
    assert a1.state is SessionState.CANCELLED and a1.result() == []
    assert qm.charged_uids() == ()
    assert qm.usage()["A"] == {"sessions": 0, "pages": 0}

    # paused-while-charged twin: cancel must return the charge too
    qm2 = QuotaManager({"A": TenantQuota(max_pages=8)})
    eng2 = Engine(m, params, batch=1, max_len=64, page_size=16,
                  scheduler=FairScheduler(quantum=1), spill="host",
                  quota=qm2)
    p0 = eng2.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                             max_new_tokens=8, tenant="A"))
    p1 = eng2.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 2,
                             max_new_tokens=8, tenant="A"))
    eng2.step()                                 # p0 resident
    eng2.step()                                 # p0 paused (quantum), p1 in
    assert p0.state is SessionState.PAUSED and qm2.charge_of(0) is not None
    p0.cancel()
    eng2.run()
    assert len(p1.result()) == 8
    assert qm2.charged_uids() == ()
    assert qm2.usage()["A"] == {"sessions": 0, "pages": 0}


# ---------------------------------------------------------------------------
# role plumbing guards
def test_role_guards(model_and_params):
    m, params = model_and_params
    with pytest.raises(ValueError):
        Engine(m, params, batch=1, max_len=32, role="prefill")  # no queue
    with pytest.raises(ValueError):
        Engine(m, params, batch=1, max_len=32, role="encode")
    pair = build_disagg(m, params, batch=1, max_len=32, page_size=16,
                        transfer="host", spill="host")
    with pytest.raises(RuntimeError):
        pair.decode.submit(Request(uid=0, prompt=np.zeros(2, np.int32)))
    with pytest.raises(ValueError):             # mismatched geometry
        DisaggPair(pair.prefill,
                   Engine(m, params, batch=1, max_len=64, page_size=16,
                          spill="host", role="decode",
                          transfer=pair.transfer),
                   pair.transfer)
    with pytest.raises(ValueError):             # page_size must tile slots
        Engine(m, params, batch=1, max_len=40, page_size=16, spill=None,
               role="prefill", transfer=pair.transfer)


def test_prefill_side_terminal_requests_never_ship(model_and_params):
    """Rejections and instant finishes (max_new_tokens=1: the prefill
    token IS the stream) retire on the prefill side — the decode side
    never sees them, and the streams still match colocated."""
    m, params = model_and_params
    pair = build_disagg(m, params, batch=2, max_len=32, page_size=16,
                        transfer="host", spill="host")
    too_long = pair.submit(Request(
        uid=0, prompt=np.arange(32, dtype=np.int32), max_new_tokens=4))
    instant = pair.submit(Request(
        uid=1, prompt=np.arange(4, dtype=np.int32) + 1, max_new_tokens=1))
    normal = pair.submit(Request(
        uid=2, prompt=np.arange(5, dtype=np.int32) + 2, max_new_tokens=4))
    done = pair.run()
    assert too_long.finish_reason == "rejected"
    assert instant.finish_reason == "length"
    assert instant.result() == _solo(m, params,
                                     np.arange(4, dtype=np.int32) + 1, 1)
    assert normal.result() == _solo(m, params,
                                    np.arange(5, dtype=np.int32) + 2, 4)
    assert pair.transfer.published == 1         # only the normal request
    assert {r.uid for r in done} == {0, 1, 2}


# ---------------------------------------------------------------------------
# hybrid (SSM + shared-attention) arch: slot-shaped state must ship too
@pytest.fixture(scope="module")
def hybrid_model():
    cfg = ARCHS["zamba2-2.7b"].reduced()
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 2, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _hybrid_solo(m, params, prompt, n_new):
    eng = Engine(m, params, batch=1, max_len=32)
    sess = eng.submit(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                              max_new_tokens=n_new))
    eng.run()
    return sess.result()


def test_hybrid_prefill_never_reads_stale_slot_state(hybrid_model):
    """Regression: prefill used to seed the SSM recurrence from the
    slot's cache — a REUSED slot then leaked the previous occupant's
    state into the next session's stream (KV rows are masked by
    cache_index, recurrent state is read-at-start).  Sequential sessions
    through one slot must match their solo decodes."""
    m, params = hybrid_model
    cfg = m.cfg
    prompts = [((np.arange(4 + i, dtype=np.int32) * (i + 2) + 1)
                % cfg.vocab_size) for i in range(2)]
    want = [_hybrid_solo(m, params, p, 5) for p in prompts]
    eng = Engine(m, params, batch=1, max_len=32)
    ss = [eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
          for i, p in enumerate(prompts)]
    eng.run()
    assert [s.result() for s in ss] == want


def test_hybrid_disagg_ships_slot_state_bit_identical(hybrid_model):
    """The handoff's slot-shaped leg: SSM conv/state rides next to the KV
    pages, and the adopted stream stays bit-identical to colocated."""
    m, params = hybrid_model
    cfg = m.cfg
    prompts = [((np.arange(4 + i, dtype=np.int32) * (i + 2) + 1)
                % cfg.vocab_size) for i in range(2)]
    want = [_hybrid_solo(m, params, p, 5) for p in prompts]
    pair = build_disagg(m, params, batch=2, max_len=32, page_size=16,
                        transfer="host", spill="host")
    ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=5))
          for i, p in enumerate(prompts)]
    pair.run()
    assert [s.result() for s in ss] == want
    # the slot-shaped leaves really travelled through the queue: more
    # publish legs than the page (k/v) leaves alone account for
    rep = pair.transfer.traffic_report()
    page_leaf_count = len(jax.tree_util.tree_leaves(
        tfm.page_slice(pair.decode.cache.pool, 0)))
    shipped = rep["transfer"]["shipped_pages"]
    assert rep["kv_publish"]["calls"] > shipped * page_leaf_count
    assert rep["kv_adopt"]["calls"] == rep["kv_publish"]["calls"]


# ---------------------------------------------------------------------------
# TransferQueue ordering (trace driver shared with the property suite)
class LedgerRuntime:
    """Duck-typed MemoryRuntime twin: payload handles in a dict, so the
    trace driver can assert every stashed page is fetched-or-discarded
    exactly once (nothing leaks, nothing is fetched twice)."""

    def __init__(self):
        self.store = {}
        self._next = 0
        self.fetches = 0
        self.discards = 0

    def stash(self, x, hints=None, direction=""):
        self._next += 1
        self.store[self._next] = x
        return self._next

    def fetch(self, payload, hints=None, direction=""):
        self.fetches += 1
        return self.store[payload]

    def discard(self, payload):
        self.discards += 1
        self.store.pop(payload, None)

    def traffic_report(self):
        return {"tier": "ledger"}


def test_transfer_max_depth_bounds_prefill_burst(model_and_params):
    """Regression: the admission gate must count residents not yet
    published — a multi-slot prefill burst used to overshoot max_depth
    because publish is unconditional."""
    m, params = model_and_params
    pair = build_disagg(m, params, batch=2, max_len=32, page_size=16,
                        prefill_batch=3, max_depth=1, transfer="host",
                        spill="host")
    ss = [pair.submit(Request(uid=i,
                              prompt=np.arange(4, dtype=np.int32) + i,
                              max_new_tokens=3)) for i in range(4)]
    for _ in range(3):                  # prefill alone can never exceed it
        pair.prefill.step()
        assert pair.transfer.depth() <= 1
    pair.run()
    assert [len(s.result()) for s in ss] == [3, 3, 3, 3]


def test_standalone_prefill_run_stops_when_queue_full(model_and_params):
    """Regression: a prefill-role engine with no consumer used to spin
    max_steps no-op rounds once the queue filled; it must stop, leaving
    the unshipped prompts visibly waiting."""
    m, params = model_and_params
    q = TransferQueue(LedgerRuntime(), max_depth=2)
    eng = Engine(m, params, batch=1, max_len=32, page_size=16, spill=None,
                 scheduler="deadline", role="prefill", transfer=q)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=4))
    eng.run(max_steps=50)
    assert q.depth() == 2               # filled to the cap, then stopped
    assert len(eng.scheduler.waiting()) == 2    # not silently dropped
    assert eng.scheduler.now < 10       # ...and it stopped EARLY (the
    #                                     deadline clock counts steps)


def _mk_handoff(uid, n_pages):
    req = Request(uid=uid, prompt=np.zeros(2, np.int32))
    sess = Session(request=req, seq=uid)
    pages = [{"kv": np.full((2,), uid * 1000 + pos, np.int32)}
             for pos in range(n_pages)]
    return KVHandoff(session=sess, length=n_pages), pages


def run_transfer_queue_trace(ops, max_depth=None, make_queue=None):
    """Drive a TransferQueue through publish/adopt/defer/cancel steps.

    Invariants asserted (the ISSUE's list):
    * FIFO per session — pages come back in logical position order with
      the exact values published;
    * delivery exactly once — no handoff is lost or adopted twice;
    * no starvation — after a backpressure requeue, every other handoff
      then parked is offered before the requeued one comes around again;
    * no payload leak — at drain, every stashed page was fetched or
      discarded and the ledger is empty.

    ``make_queue(max_depth) -> (queue, leak_check)`` swaps the queue
    under test: the default is the in-process loopback; the wire suite
    (tests/test_router.py) passes a byte-serialized sender/receiver glue
    so the SAME invariants pin the transport.  The queue must expose the
    TransferQueue surface plus ``_parked`` (handoffs with ``.uid`` /
    ``.session``) and ``adopted_pages``.
    """
    if make_queue is None:
        def make_queue(depth):
            runtime = LedgerRuntime()
            queue = TransferQueue(runtime, max_depth=depth)

            def leak_check():
                assert not runtime.store, \
                    "payloads leaked in the transfer tier"
            return queue, leak_check
    q, leak_check = make_queue(max_depth)
    uid = 0
    published, adopted, cancelled = {}, set(), set()
    waiting_for = {}        # uid -> uids that must be offered before it
    for op, arg in ops:
        if op == "publish":
            if not q.has_room():
                continue
            handoff, pages = _mk_handoff(uid, n_pages=1 + arg % 3)
            q.publish(handoff, pages)
            published[uid] = pages
            uid += 1
        elif op == "adopt":
            h = q.next_ready()
            if h is None:
                continue
            for blocked, others in list(waiting_for.items()):
                others.discard(h.uid)
            if h.uid in waiting_for:
                assert not waiting_for.pop(h.uid), \
                    f"handoff {h.uid} starved its queue peers"
            if h.session.done:
                q.discard(h)
                cancelled.add(h.uid)
                continue
            if arg % 2:                         # decode-side backpressure
                others = set(q.parked_uids())
                q.requeue(h)
                waiting_for[h.uid] = others
                continue
            pages = q.fetch_pages(h)
            assert h.uid not in adopted, f"handoff {h.uid} adopted twice"
            adopted.add(h.uid)
            want = published[h.uid]
            assert len(pages) == len(want)
            for got, exp in zip(pages, want):   # FIFO per session
                np.testing.assert_array_equal(got["kv"], exp["kv"])
        elif op == "cancel" and published:
            victim = sorted(published)[arg % len(published)]
            if victim not in adopted and victim not in cancelled:
                # find the parked handoff's session and cancel it
                for h in q._parked:
                    if h.uid == victim:
                        h.session.cancel()
                        break
        for sess in q.sweep_cancelled():
            cancelled.add(sess.uid)
            # a swept peer can no longer be "offered" — it must not
            # count against the fairness ledger of requeued handoffs
            for others in waiting_for.values():
                others.discard(sess.uid)
    # drain: adopt everything left, no backpressure
    while True:
        h = q.next_ready()
        if h is None:
            break
        if h.session.done:
            q.discard(h)
            cancelled.add(h.uid)
            continue
        q.fetch_pages(h)
        assert h.uid not in adopted
        adopted.add(h.uid)
    swept = {u for u in published
             if u not in adopted and u not in cancelled}
    # cancelled-in-queue sessions were swept by sweep_cancelled
    assert all(u not in adopted for u in swept)
    leak_check()
    assert q.adopted_pages == sum(len(published[u]) for u in adopted)
    return q, adopted


def test_transfer_queue_random_traces_seeded():
    rng = random.Random(4321)
    for _ in range(30):
        ops = [(rng.choice(["publish", "adopt", "adopt", "cancel"]),
                rng.randrange(16)) for _ in range(60)]
        q, adopted = run_transfer_queue_trace(
            ops, max_depth=rng.choice([None, 2, 4]))
        assert q.depth() == 0


# ---------------------------------------------------------------------------
# DeadlineScheduler under staggered arrivals (driver shared with the
# property suite: misses are monotone in uniform deadline slack)
def run_deadline_sim(jobs, slots=2, slack=0, max_steps=500):
    """Pure-python twin of the engine's deadline serving loop.

    ``jobs``: (arrival_step, service_tokens, base_deadline|None) triples.
    EDF admission from the real DeadlineScheduler into ``slots``; each
    step every running session decodes one token; retirement feeds the
    met/missed accounting.  Uniform ``slack`` is added to every real
    deadline — it preserves every EDF comparison, so the schedule is
    identical and misses can only go down.
    """
    sched = build_scheduler("deadline")
    pending = sorted(((arr, i, svc, dl) for i, (arr, svc, dl)
                      in enumerate(jobs)), key=lambda t: t[:2])
    running, sessions = [], []
    for t in range(max_steps):
        while pending and pending[0][0] <= t:
            _, i, svc, dl = pending.pop(0)
            req = Request(uid=i, prompt=np.zeros(2, np.int32),
                          max_new_tokens=svc,
                          deadline=None if dl is None else dl + slack)
            sess = Session(request=req, seq=i)
            sessions.append(sess)
            sched.submit(sess)
        # preemption, as the engine drives it: waiting work beyond the
        # free slots may displace running sessions the policy outranks
        free = slots - len(running)
        while free < len(sched.waiting()):
            victim = sched.preempt_victim(running)
            if victim is None:
                break
            running.remove(victim)
            victim.preemptions += 1
            sched.requeue(victim)
            free += 1
        while len(running) < slots:
            nxt = sched.next_ready()
            if nxt is None:
                break
            running.append(nxt)
        sched.on_step()
        for sess in list(running):
            sess.emit(0)
            if len(sess.tokens) >= sess.request.max_new_tokens:
                sess.finish("length")
                sched.on_retire(sess)
                running.remove(sess)
        if not running and not pending and not sched.has_waiting():
            break
    assert not pending and not running, "sim did not drain"
    served = sum(1 for s in sessions if s.deadline != float("inf"))
    rep = sched.miss_report()
    assert rep["met"] + rep["missed"] == served
    return sched


def test_deadline_misses_monotone_in_slack_seeded():
    rng = random.Random(7)
    for _ in range(25):
        jobs = [(rng.randrange(0, 10), rng.randrange(1, 8),
                 rng.choice([None] + list(range(1, 25))))
                for _ in range(rng.randrange(1, 12))]
        slots = rng.randrange(1, 4)
        misses = [run_deadline_sim(jobs, slots=slots, slack=s).misses
                  for s in (0, 3, 10)]
        assert misses[0] >= misses[1] >= misses[2], (jobs, slots, misses)


def test_deadline_sim_lateness_and_tenant_split():
    """met/missed under staggered arrivals: a tight deadline arriving
    behind a long job misses with positive max_lateness; the generous
    one meets."""
    sched = run_deadline_sim(
        [(0, 6, 30), (2, 3, 4)], slots=1, slack=0)
    rep = sched.miss_report()
    assert rep == {"now": rep["now"], "met": 1, "missed": 1,
                   "max_lateness": rep["max_lateness"],
                   "by_tenant": {"default": {"met": 1, "missed": 1}}}
    assert rep["max_lateness"] >= 1


def test_deadline_staggered_arrivals_engine(model_and_params):
    """Engine-level staggered arrivals: submissions landing mid-run feed
    the same met/missed accounting (served sessions only)."""
    m, params = model_and_params
    eng = Engine(m, params, batch=1, max_len=64, scheduler="deadline")
    generous = eng.submit(Request(uid=0,
                                  prompt=np.arange(4, dtype=np.int32) + 1,
                                  max_new_tokens=4, deadline=40))
    eng.step()
    eng.step()
    tight = eng.submit(Request(uid=1,
                               prompt=np.arange(5, dtype=np.int32) + 2,
                               max_new_tokens=4, deadline=3))
    eng.run()
    rep = eng.scheduler.miss_report()
    assert rep["met"] + rep["missed"] == 2
    assert rep["missed"] >= 1 and rep["max_lateness"] >= 1
    assert generous.finish_reason == tight.finish_reason == "length"
