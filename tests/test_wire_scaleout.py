"""Scale-out wire (PR 10): striped multi-stream transport, the shm
zero-copy same-host path, and router federation.

Invariants pinned here (the ISSUE's list):
* striped and shm wires stream bit-identical to the loopback — including
  compressed pages and the cancel/requeue paths;
* ``kv_wire`` metering reconciles byte-exactly when summed across
  stripes, with and without a mid-handoff stripe death;
* a stripe dying mid-handoff surfaces :class:`TransportError`, the
  session requeues, and the PR 8 ``Session.emitted`` high-water guard
  keeps the client stream free of repeats;
* a poisoned channel (mid-frame retry exhaustion) fails fast on the next
  call instead of parsing payload bytes as a header;
* federation forwards overflow, keeps the shared quota ledger
  consistent via remote-usage overlays, and drops zero sessions on
  peer drain or peer death.

The striped-reassembly trace driver at the top is shared with the
hypothesis property suite (tests/test_serve_properties.py); the seeded
trace here covers the machinery when hypothesis is not installed.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, RunConfig
from repro.configs.base import MeshPlan, ShapeConfig
from repro.models.model import build_model
from repro.serve.disagg import build_disagg
from repro.serve.engine import Request
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.router import (FOREIGN_UID_BASE, FederatedRouter,
                                build_router, federate)
from repro.serve import transport as tp
from repro.serve.transport import (ShmChannel, StripedChannel,
                                   TransportError, build_wire_pair,
                                   memory_pair, pack_frame, recv_frame,
                                   shm_pair, striped_pair)

from test_transport import FlakyChannel

CFG = ARCHS["smollm-135m"].reduced()


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=MeshPlan((1,), ("data",)),
                    memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _prompts(n, base=4):
    return [((np.arange(base + i, dtype=np.int32) * (i + 2) + 1)
             % CFG.vocab_size) for i in range(n)]


def _drive(pair, prompts, new_tokens=6):
    ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
          for i, p in enumerate(prompts)]
    pair.run()
    return [s.result() for s in ss]


# ---------------------------------------------------------------------------
# striped reassembly trace driver (shared with the hypothesis suite)
def run_striped_reassembly_trace(msgs, streams, max_chunk,
                                 deadline_s=30.0):
    """Replay one control/handoff message trace over a striped pair AND
    a single-stream pair carrying identical messages.

    ``msgs``: a list of ``("ctrl", kind, val)`` control messages and
    ``("handoff", [page_blob, ...])`` handoffs whose pages are arbitrary
    byte blobs.  Returns ``(striped_seq, single_seq, striped_meter,
    single_meter)`` where each ``seq`` is the delivered ``(kind, msg)``
    list and each ``meter`` is ``(sum of per-send returns,
    channel.bytes_sent)`` — the reconciliation the live wire relies on.
    """
    stx, srx = striped_pair(streams, base="memory", max_chunk=max_chunk)
    mtx, mrx = memory_pair(max_chunk)
    try:
        s_total = m_total = 0
        for m in msgs:
            if m[0] == "handoff":
                pages = [np.frombuffer(b, dtype=np.uint8).copy()
                         for b in m[1]]
                hdr = {"schema": tp.SCHEMA_VERSION, "uid": len(pages),
                       "pages": [], "slot_one": None}
                s_total += tp._send_handoff_msg(stx, dict(hdr), pages)
                m_total += tp._send_handoff_msg(mtx, dict(hdr), pages)
            else:
                _, kind, val = m
                s_total += tp._send_msg(stx, kind, {"uid": val})
                m_total += tp._send_msg(mtx, kind, {"uid": val})

        def drain(ch):
            out, t0 = [], time.time()
            while len(out) < len(msgs):
                got = tp._poll_msg(ch, retries=4, backoff=0.0,
                                   sleep=lambda s: None)
                if got is None:
                    assert time.time() - t0 < deadline_s, \
                        "striped reassembly stalled"
                    time.sleep(0.001)
                    continue
                out.append(got)
            return out

        striped_seq = drain(srx)
        single_seq = drain(mrx)
        return (striped_seq, single_seq,
                (s_total, stx.bytes_sent), (m_total, mtx.bytes_sent))
    finally:
        stx.close()
        srx.close()


def msg_seqs_equal(a, b):
    """Delivered sequences match: same kinds, same payloads, with page
    arrays compared element-exact."""
    if len(a) != len(b):
        return False
    for (ka, ma), (kb, mb) in zip(a, b):
        if ka != kb or set(ma) != set(mb):
            return False
        for key in ma:
            va, vb = ma[key], mb[key]
            if key == "pages":
                if len(va) != len(vb) or not all(
                        np.array_equal(x, y) for x, y in zip(va, vb)):
                    return False
            elif va != vb:
                return False
    return True


@pytest.mark.parametrize("streams,max_chunk",
                         [(1, None), (2, None), (3, 7), (4, 127)])
def test_striped_reassembly_seeded(streams, max_chunk):
    """Seeded twin of the hypothesis property: random page sizes with
    interleaved control frames reproduce the single-stream sequence and
    metering exactly, through fragmented reads."""
    rng = np.random.default_rng(streams * 1000 + (max_chunk or 0))
    ctrl_kinds = (tp.K_ACK, tp.K_CANCEL, tp.K_RESULT)
    msgs = []
    for i in range(8):
        if i % 3 == 2:
            msgs.append(("ctrl", ctrl_kinds[i % len(ctrl_kinds)], i))
        else:
            blobs = [rng.bytes(int(n)) for n in rng.integers(0, 2048,
                                                             size=i % 4)]
            msgs.append(("handoff", blobs))
    msgs.append(("ctrl", tp.K_RESULT, 99))
    striped, single, s_meter, m_meter = run_striped_reassembly_trace(
        msgs, streams, max_chunk)
    assert msg_seqs_equal(striped, single)
    assert s_meter[0] == s_meter[1], \
        "summed send returns != summed stripe bytes_sent"
    assert m_meter[0] == m_meter[1]


# ---------------------------------------------------------------------------
# bit-identity: striped and shm wires == loopback
@pytest.fixture(scope="module")
def loopback_want(model_and_params):
    m, params = model_and_params
    prompts = _prompts(5)
    loop = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    return prompts, _drive(loop, prompts)


def test_striped_wire_identical_to_loopback(model_and_params,
                                            loopback_want):
    m, params = model_and_params
    prompts, want = loopback_want
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", streams=4)
    assert isinstance(wire.sender.channel, StripedChannel)
    assert _drive(wire, prompts) == want
    out = wire.traffic_report()["wire_out"]["transfer"]
    inn = wire.traffic_report()["wire_in"]["transfer"]
    assert out["published"] == inn["published"] == 5
    assert out["depth"] == inn["depth"] == 0


def test_striped_wire_identical_through_fragmented_stripes(
        model_and_params, loopback_want):
    """127-byte reads on every stripe: per-stripe reassembly plus
    cross-stripe reordering never corrupts a page."""
    m, params = model_and_params
    prompts, want = loopback_want
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host",
                           channels=striped_pair(3, max_chunk=127))
    assert _drive(wire, prompts) == want


def test_striped_kv_wire_reconciles_across_stripes(model_and_params):
    """Acceptance: summed ``kv_wire`` equals every byte that crossed any
    stripe, and the payload really fans out beyond stripe 0."""
    m, params = model_and_params
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", streams=4)
    _drive(wire, _prompts(4, base=18))
    rep = wire.traffic_report()
    out_wire = rep["wire_out"]["kv_wire"]
    chan = wire.sender.channel
    assert out_wire["wire_bytes"] == chan.bytes_sent == \
        sum(s.bytes_sent for s in chan.stripes)
    assert sum(1 for s in chan.stripes if s.bytes_sent > 0) >= 2, \
        "pages never left stripe 0 — striping is not engaged"
    pub = rep["wire_out"]["kv_publish"]
    adopt = rep["wire_in"]["kv_adopt"]
    assert pub["wire_bytes"] == adopt["wire_bytes"] > 0
    assert pub["raw_bytes"] == adopt["raw_bytes"]


def test_striped_codec_matches_single_stream_codec(model_and_params):
    """Compressed pages across stripes: identical streams to the same
    codec on a single-stream wire, at the same (reduced) publish bytes."""
    m, params = model_and_params
    prompts = _prompts(3, base=18)
    single = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                             spill="host", wire_codec="int8")
    want = _drive(single, prompts)
    striped = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                              spill="host", wire_codec="int8", streams=3)
    assert _drive(striped, prompts) == want
    s_pub = single.traffic_report()["wire_out"]["kv_publish"]
    t_pub = striped.traffic_report()["wire_out"]["kv_publish"]
    assert t_pub["wire_bytes"] == s_pub["wire_bytes"] < s_pub["raw_bytes"]


def test_cancel_in_transit_over_striped_wire(model_and_params):
    m, params = model_and_params
    quota = QuotaManager(default_quota=TenantQuota(max_pages=64))
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", quota=quota, streams=3)
    ss = [wire.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(3))]
    wire.prefill.step()
    ss[1].cancel()
    wire.run()
    assert ss[1].finish_reason == "cancelled"
    assert ss[0].done and ss[2].done
    assert quota.charged_uids() == ()


# ---------------------------------------------------------------------------
# shm: zero-copy same-host path
def test_shm_wire_identical_to_loopback(model_and_params, loopback_want):
    m, params = model_and_params
    prompts, want = loopback_want
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", transport="shm")
    assert isinstance(wire.sender.channel, ShmChannel)
    assert _drive(wire, prompts) == want


def test_shm_wire_bytes_are_header_only(model_and_params):
    """The whole point of the arena: ``kv_wire`` meters only the header
    frames that crossed the socket, while publish/adopt still reconcile
    the full tensor payload — and every arena block is freed by ACK."""
    m, params = model_and_params
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", transport="shm")
    _drive(wire, _prompts(4, base=18))
    rep = wire.traffic_report()
    out_wire = rep["wire_out"]["kv_wire"]
    pub = rep["wire_out"]["kv_publish"]
    adopt = rep["wire_in"]["kv_adopt"]
    chan = wire.sender.channel
    assert out_wire["wire_bytes"] == chan.bytes_sent
    assert out_wire["wire_bytes"] < pub["wire_bytes"], \
        "shm headers should be far smaller than the tensor payload"
    assert pub["wire_bytes"] == adopt["wire_bytes"] > 0
    assert pub["raw_bytes"] == adopt["raw_bytes"]
    assert not chan._allocs, "arena blocks leaked past their ACKs"
    arena = chan._arena
    assert arena is not None and arena.free_bytes() == arena.size


def test_cancel_in_transit_over_shm(model_and_params):
    m, params = model_and_params
    quota = QuotaManager(default_quota=TenantQuota(max_pages=64))
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", quota=quota, transport="shm")
    ss = [wire.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(3))]
    wire.prefill.step()
    ss[1].cancel()
    wire.run()
    assert ss[1].finish_reason == "cancelled"
    assert ss[0].done and ss[2].done
    assert quota.charged_uids() == ()
    assert not wire.sender.channel._allocs


# ---------------------------------------------------------------------------
# faults: stripe death mid-handoff, poisoning
def test_stripe_death_mid_handoff_requeues_no_double_emit(
        model_and_params):
    """A stripe dying mid-handoff surfaces TransportError, the engine
    requeues via ``Session.rewind``, and the ``emitted`` high-water mark
    keeps the replay from notifying any position twice."""
    m, params = model_and_params
    prompts = _prompts(4, base=18)      # 2 pages each: stripe 1 carries
    loop = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    want = _drive(loop, prompts)

    pairs = [memory_pair() for _ in range(3)]
    tx_stripes = [p[0] for p in pairs]
    flaky = FlakyChannel(tx_stripes[1], fail_on=1)
    tx_stripes[1] = flaky
    stx = StripedChannel(tx_stripes)
    srx = StripedChannel([p[1] for p in pairs])
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", channels=(stx, srx))
    notified = {}
    ss = []
    for i, p in enumerate(prompts):
        s = wire.submit(Request(uid=i, prompt=p, max_new_tokens=6))
        s.on_token = lambda sess, tok: notified.setdefault(
            sess.uid, []).append(tok)
        ss.append(s)
    wire.run()
    assert flaky.sends >= 2, "the injected stripe death never fired"
    assert [s.result() for s in ss] == want
    assert all(s.finish_reason == "length" for s in ss)
    # the requeued session re-ran its prefill (regenerating position 1),
    # but the emitted high-water mark notified the client exactly once
    for s in ss:
        assert notified[s.uid] == list(s.tokens[:1])
    # metering still reconciles: the partial (failed) handoff's bytes
    # were metered off err.wire_bytes
    out_wire = wire.traffic_report()["wire_out"]["kv_wire"]
    assert out_wire["wire_bytes"] == stx.bytes_sent


def test_poisoned_channel_fails_fast():
    """Satellite bugfix: after a mid-frame retry exhaustion the channel
    is poisoned — the next call refuses to parse the (desynchronized)
    byte stream, even if a healthy frame arrives later."""
    a, b = memory_pair()
    frame = pack_frame(tp.K_ACK, b"\x80\x04N.")
    a.send(frame[: len(frame) - 3])         # starve mid-frame
    with pytest.raises(TransportError, match="partial read"):
        recv_frame(b, retries=2, backoff=0.0, sleep=lambda s: None)
    a.send(frame[len(frame) - 3:])          # stream is whole again, but
    with pytest.raises(TransportError, match="poisoned"):
        recv_frame(b, retries=2, backoff=0.0, sleep=lambda s: None)


def test_striped_rx_corruption_poisons_whole_channel():
    """Garbage on ONE stripe fails the striped channel fast on every
    later call instead of delivering a torn message stream."""
    stx, srx = striped_pair(3)
    try:
        stx.stripes[1].send(b"XXgarbage-not-a-frame" * 4)
        with pytest.raises(TransportError, match="stripe 1"):
            deadline = time.time() + 10.0
            while time.time() < deadline:   # rx worker notices async
                srx.poll_msg()
                time.sleep(0.002)
            pytest.fail("stripe corruption never surfaced")
        with pytest.raises(TransportError, match="poisoned"):
            srx.poll_msg()
    finally:
        stx.close()
        srx.close()


# ---------------------------------------------------------------------------
# federation
def _run_feds(feds, max_steps=20_000):
    for _ in range(max_steps):
        if not any(f.has_work() for f in feds):
            return
        for f in feds:
            f.step()
    raise AssertionError("federation never drained")


def _fed_pair(m, params, **kw):
    r0 = build_router(m, params, engines=1, batch=2, max_len=64,
                      page_size=16, transfer="host", spill="host", **kw)
    r1 = build_router(m, params, engines=1, batch=2, max_len=64,
                      page_size=16, transfer="host", spill="host", **kw)
    return federate([r0, r1])


def test_federation_forwards_overflow(model_and_params):
    """Cluster 0's backlog spills to cluster 1 and every stream comes
    home: forwarded == adopted, zero dropped sessions."""
    m, params = model_and_params
    fed0, fed1 = _fed_pair(m, params)
    ss = [fed0.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(8))]
    _run_feds([fed0, fed1])
    assert all(s.done for s in ss)
    assert all(len(s.tokens) == 4 and s.finish_reason == "length"
               for s in ss)
    assert fed0.forwarded > 0
    assert fed1.adopted == fed0.forwarded
    # foreign uids never collide with origin-minted ones
    assert all(uid >= FOREIGN_UID_BASE for uid in fed1._foreign)
    # the Request.out_tokens alias survived the round trip
    assert all(s.request.out_tokens is s.tokens for s in ss)


def test_federation_quota_overlay_counts_remote_holdings():
    """One tenant's budget binds over local + remote holdings, and a
    dropped peer releases its overlay."""
    q = QuotaManager({"t": TenantQuota(max_sessions=4, max_pages=100)})
    assert q.can_admit("t", pages=10)
    q.set_remote_usage("peer-a", {"t": {"sessions": 3, "pages": 80}})
    assert q.remote_peers() == ("peer-a",)
    assert q.can_admit("t", pages=10)          # 0+3+1 sessions, 90 pages
    assert not q.can_admit("t", pages=30)      # 110 pages > 100
    q.set_remote_usage("peer-b", {"t": {"sessions": 1, "pages": 0}})
    assert not q.can_admit("t", pages=1)       # 0+4+1 sessions > 4
    q.set_remote_usage("peer-a", None)
    assert q.can_admit("t", pages=30)


def test_federation_drain_rejects_and_requeues(model_and_params):
    """A forward racing a peer's drain is rejected (FWD_REJECT) and the
    origin serves it locally — zero dropped sessions."""
    m, params = model_and_params
    fed0, fed1 = _fed_pair(m, params)
    ss = [fed0.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(8))]
    # fed1 advertises headroom; fed0 forwards into it while fed1 sits
    # idle — then the drain begins with those forwards still in flight
    fed1.step()
    for _ in range(200):
        fed0.step()
        if fed0.forwarded > 0:
            break
    assert fed0.forwarded > 0
    fed1.drain()
    _run_feds([fed0, fed1])
    assert fed1.rejected == fed0.forwarded
    assert fed0.router.requeues >= fed0.forwarded
    assert all(s.done and len(s.tokens) == 4 for s in ss)
    assert fed1.adopted == 0


def test_federation_dead_peer_requeues_outstanding(model_and_params):
    """A peer that vanishes mid-flight: its forwarded sessions rewind
    and finish locally; the remote-usage overlay is dropped."""
    m, params = model_and_params
    quota = QuotaManager(default_quota=TenantQuota(max_pages=1000))
    r0 = build_router(m, params, engines=1, batch=2, max_len=64,
                      page_size=16, transfer="host", spill="host",
                      quota=quota)
    r1 = build_router(m, params, engines=1, batch=2, max_len=64,
                      page_size=16, transfer="host", spill="host")
    fed0, fed1 = federate([r0, r1])
    ss = [fed0.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(8))]
    for _ in range(200):
        fed0.step()
        fed1.step()
        if fed0.forwarded > 0:
            break
    assert fed0.forwarded > 0
    fed0.peers["cluster1"].channel.close()     # peer vanishes
    _run_feds([fed0])
    assert fed0.peers["cluster1"].closed
    assert all(s.done and len(s.tokens) == 4 for s in ss)
    assert r0.requeues >= fed0.forwarded
    assert quota.remote_peers() == ()
    assert quota.charged_uids() == ()
