"""Synthetic traffic generator (sim/workloads.py) and the analytic
serving replay (sim/simulator.simulate_serving) that evaluates the REAL
placement registry against DC/HC/MC system configs at full scale."""
import math

import numpy as np
import pytest

from repro.sim.simulator import (ModelProfile, ServingReport,
                                 serving_table, simulate_serving)
from repro.sim.topology import DC_DLA, HC_DLA, MC_DLA_B
from repro.sim.workloads import (SyntheticSession, TrafficSpec,
                                 generate_traffic, traffic_summary)

SPEC = TrafficSpec(sessions=2000, horizon_s=86_400.0, seed=11)


@pytest.fixture(scope="module")
def trace():
    return generate_traffic(SPEC)


def test_generator_deterministic(trace):
    again = generate_traffic(SPEC)
    assert again == trace                   # frozen dataclasses compare
    other = generate_traffic(
        TrafficSpec(sessions=2000, horizon_s=86_400.0, seed=12))
    assert other != trace


def test_trace_shape(trace):
    assert len(trace) == SPEC.sessions
    arrivals = [s.arrival for s in trace]
    assert arrivals == sorted(arrivals)
    assert 0.0 <= min(arrivals) and max(arrivals) <= SPEC.horizon_s
    for s in trace:
        assert 1 <= s.prompt_len <= SPEC.prompt_max
        assert 1 <= s.decode_len <= SPEC.decode_max
        assert s.slo in ("interactive", "standard", "batch")
        assert s.tenant in SPEC.tenants
        if s.prefix_id is None:
            assert s.prefix_len == 0
        else:
            assert 0 <= s.prefix_id < SPEC.prefix_pool
            # a shared prefix only exists inside a longer prompt
            assert s.prompt_len > s.prefix_len == SPEC.prefix_len


def test_slo_slack_contract(trace):
    slack_of = {name: slack for name, _, slack in SPEC.slo_classes}
    for s in trace:
        if slack_of[s.slo] is None:
            assert math.isinf(s.slack_steps)        # batch: no deadline
        else:
            assert s.slack_steps == slack_of[s.slo] * s.decode_len


def test_mix_matches_spec(trace):
    summary = traffic_summary(trace)
    assert summary["sessions"] == SPEC.sessions
    # weights are sampled; on 2000 sessions the mix lands within a few %
    assert abs(summary["by_slo"]["standard"] / SPEC.sessions - 0.5) < 0.1
    assert abs(summary["by_tenant"]["default"] / SPEC.sessions - 0.6) < 0.1
    assert abs(summary["shared_prefix_frac"] - SPEC.shared_prefix_frac) < 0.1
    assert summary["mean_prompt"] < SPEC.prompt_max


def test_diurnal_concentration():
    """With a strong diurnal cycle and no bursts, the peak hour gets
    several times the traffic of the trough hour."""
    spec = TrafficSpec(sessions=5000, diurnal_amplitude=0.9,
                       peak_hour=14.0, burst_rate_per_hour=0.0, seed=3)
    trace = generate_traffic(spec)
    hour = lambda s: int(s.arrival // 3600) % 24
    counts = [0] * 24
    for s in trace:
        counts[hour(s)] += 1
    assert counts[14] > 3 * max(counts[2], 1)       # trough is ~2am


def test_bursts_cluster_arrivals():
    """Burst events concentrate arrivals into tight windows: the busiest
    minute of a bursty trace far exceeds the flat trace's."""

    def busiest_minute(spec):
        trace = generate_traffic(spec)
        counts = {}
        for s in trace:
            counts[int(s.arrival // 60)] = counts.get(
                int(s.arrival // 60), 0) + 1
        return max(counts.values())

    flat = busiest_minute(TrafficSpec(
        sessions=3000, diurnal_amplitude=0.0, burst_rate_per_hour=0.0,
        seed=7))
    bursty = busiest_minute(TrafficSpec(
        sessions=3000, diurnal_amplitude=0.0, burst_rate_per_hour=4.0,
        burst_size=100, burst_spread_s=10.0, seed=7))
    assert bursty > 3 * flat


# ---------------------------------------------------------------------------
def test_simulate_serving_basic(trace):
    rep = simulate_serving(trace, MC_DLA_B, engines=4)
    assert isinstance(rep, ServingReport)
    assert rep.finished == len(trace)
    assert rep.tok_per_s > 0
    assert 0.0 < rep.ttft_mean_s <= rep.ttft_p99_s
    assert 0.0 <= rep.slo_miss_rate <= 1.0
    assert 0.0 < rep.mean_engine_util <= 1.0
    rows = rep.rows()
    assert len(rows) == 5
    assert all(name.startswith(f"{rep.system}/{rep.policy}")
               for name, _, _ in rows)


def test_serving_table_sweeps_policies_and_systems(trace):
    reports = serving_table(trace, [DC_DLA, HC_DLA, MC_DLA_B], engines=4)
    assert len(reports) == 9                    # 3 systems x 3 policies
    assert {r.policy for r in reports} == {
        "least_loaded", "prefix_affinity", "round_robin"}
    assert {r.system for r in reports} == {
        DC_DLA.name, HC_DLA.name, MC_DLA_B.name}


def test_memory_centric_tier_helps_handoff(trace):
    """The paper's thesis at serving scale: the memory-centric pool's
    fatter backing tier shortens the prefill->decode KV handoff, so
    TTFT under the same policy is no worse than the DC baseline."""
    dc = simulate_serving(trace, DC_DLA, engines=4)
    mc = simulate_serving(trace, MC_DLA_B, engines=4)
    assert mc.ttft_mean_s <= dc.ttft_mean_s
    assert mc.slo_miss_rate <= dc.slo_miss_rate


def test_heavier_model_is_slower(trace):
    small = simulate_serving(trace, MC_DLA_B, engines=4,
                             model=ModelProfile())
    big = simulate_serving(trace, MC_DLA_B, engines=4,
                           model=ModelProfile(
                               flops_per_token=2.0 * 70e9,
                               weight_bytes=140e9,
                               kv_bytes_per_token=2 * 524_288.0))
    assert big.ttft_mean_s > small.ttft_mean_s
    assert big.tok_per_s < small.tok_per_s


def test_replay_and_analytic_see_same_trace():
    """The same spec yields the same sessions for both consumers — the
    scaled-down router replay and the analytic sweep (determinism is the
    contract that makes the two comparable)."""
    spec = TrafficSpec(sessions=50, horizon_s=600.0, seed=9)
    a, b = generate_traffic(spec), generate_traffic(spec)
    assert [s.uid for s in a] == [s.uid for s in b]
    assert traffic_summary(a) == traffic_summary(b)
