"""Distributed train step (mcdla policy, 8 devices) must match the
single-device oracle bitwise-ish (fp32 tolerance)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig, TrainConfig
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.train.loop import make_train_step
from repro.train.train_state import init_state, state_shardings

cfg = dataclasses.replace(ARCHS["smollm-135m"].reduced(), dtype="float32",
                          num_heads=4, num_kv_heads=2, d_model=128)
tc = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=10)
B, S = 8, 32
shape = ShapeConfig("t", S, B, "train")
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
    "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
}

# single-device oracle
run1 = RunConfig(model=cfg, shape=shape, mesh=MeshPlan((1,), ("data",)),
                 memory=MemoryPlan(policy="none"), train=tc)
m1 = build_model(run1)
s1 = init_state(m1, tc)
step1 = make_train_step(m1, tc)
s1b, metrics1 = jax.jit(step1)(s1, batch)

# 8-device mcdla
mesh = jax.make_mesh((4, 2), ("data", "model"))
run8 = RunConfig(model=cfg, shape=shape, mesh=MeshPlan((4, 2), ("data", "model")),
                 memory=MemoryPlan(policy="mcdla", placement="bw_aware"), train=tc)
m8 = build_model(run8, mesh=mesh)
s8 = init_state(m8, tc)     # same seed -> identical init
sh = state_shardings(m8, tc)
with mesh:
    s8 = jax.tree.map(lambda x, s: jax.device_put(x, s), s8, sh)
    bsh = {k: NamedSharding(mesh, m8.batch_specs(shape)[k]) for k in batch}
    batch8 = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
    step8 = make_train_step(m8, tc)
    s8b, metrics8 = jax.jit(step8, in_shardings=(sh, bsh), out_shardings=(sh, None))(s8, batch8)

np.testing.assert_allclose(float(metrics1["loss"]), float(metrics8["loss"]), rtol=1e-5)
for a, b in zip(jax.tree.leaves(s1b["params"]), jax.tree.leaves(s8b["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-4)
print("sharded mcdla train step == single-device oracle OK")
