import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import MeshPlan, MemoryPlan
from repro.parallel.sharding import ShardingPlanner
from repro.core.offload import maybe_offload

mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = MeshPlan((4, 2), ("data", "model"))
planner = ShardingPlanner(plan)

def layer(params, x, pos):
    h = jnp.einsum("bsd,df->bsf", x, params["w1"])
    h = jax.nn.silu(h) + pos.astype(h.dtype)[None, :, None] * 0.0
    return x + jnp.einsum("bsf,fd->bsd", h, params["w2"])

key = jax.random.PRNGKey(0)
B, S, D, F = 8, 16, 32, 64
params = {"w1": jax.random.normal(key, (D, F)) * 0.1,
          "w2": jax.random.normal(key, (F, D)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
pos = jnp.arange(S, dtype=jnp.int32)
cs = P("data", None, None)

for policy, compress in [("none","none"), ("mcdla","none"), ("mcdla","fp8"), ("auto","none"), ("host","none")]:
    for placement in (["bw_aware","local"] if policy=="mcdla" else ["bw_aware"]):
        mem = MemoryPlan(policy=policy, placement=placement, compress=compress)
        f = maybe_offload(layer, planner, mesh, mem, compute_spec=cs)
        def loss(p, x):
            return jnp.sum(f(p, x, pos) ** 2)
        with jax.set_mesh(mesh) if hasattr(jax, 'set_mesh') else mesh:
            lj = jax.jit(loss, in_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, cs)))
            v = lj(params, x)
            g = jax.jit(jax.grad(loss, argnums=(0,1)), in_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, cs)))(params, x)
        # reference
        vref = jnp.sum(layer(params, x, pos) ** 2)
        gref = jax.grad(lambda p, x: jnp.sum(layer(p, x, pos)**2), argnums=(0,1))(params, x)
        tol = 2e-1 if compress == "fp8" else 1e-5
        if compress == "fp8":
            continue  # fp8 grads validated against the dequantized oracle in offload_fp8.py
        np.testing.assert_allclose(v, vref, rtol=1e-5)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)
        print(f"OK policy={policy} placement={placement} compress={compress} loss={float(v):.4f}")
