import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.parallel.collectives import ring_all_reduce, compressed_all_reduce

mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))

def f(xl):
    return ring_all_reduce(xl[0], "data")
out = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False)(x)
np.testing.assert_allclose(np.asarray(out), np.asarray(x.sum(0)), rtol=1e-5, atol=1e-5)
print("ring_all_reduce == sum OK")

def g(xl, el):
    m, e = compressed_all_reduce(xl[0], el[0], "data")
    return m, e
err = jnp.zeros((8, 16, 32))
m, e = shard_map(g, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P(), P("data")), check_vma=False)(x, err)
ref = x.mean(0)
rel = float(jnp.linalg.norm(m - ref) / jnp.linalg.norm(ref))
print(f"compressed_all_reduce rel err: {rel:.4f}")
assert rel < 0.02
# error feedback: the residual equals corrected - sent
print("compressed OK")
