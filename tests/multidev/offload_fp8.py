import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import MeshPlan, MemoryPlan
from repro.parallel.sharding import ShardingPlanner
from repro.core.offload import maybe_offload
from repro.core.compress import fp8_compress, fp8_decompress

mesh = jax.make_mesh((4, 2), ("data", "model"))
planner = ShardingPlanner(MeshPlan((4, 2), ("data", "model")))

def layer(params, x, pos):
    h = jnp.einsum("bsd,df->bsf", x, params["w1"])
    h = jax.nn.silu(h)
    return x + jnp.einsum("bsf,fd->bsd", h, params["w2"])

key = jax.random.PRNGKey(0)
B, S, D, F = 8, 16, 32, 64
params = {"w1": jax.random.normal(key, (D, F)) * 0.1,
          "w2": jax.random.normal(key, (F, D)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
pos = jnp.arange(S, dtype=jnp.int32)
cs = P("data", None, None)
mem = MemoryPlan(policy="mcdla", compress="fp8")
f = maybe_offload(layer, planner, mesh, mem, compute_spec=cs)

def loss(p, x): return jnp.sum(f(p, x, pos) ** 2)
with mesh:
    g = jax.jit(jax.grad(loss, argnums=(0, 1)))(params, x)

# oracle: same layer but backward built from dequantized x, forward exact
q, sc = fp8_compress(x)
x_deq = fp8_decompress(q, sc, x.dtype)
y_exact = layer(params, x, pos)
_, vjp = jax.vjp(lambda p, xx: layer(p, xx, pos), params, x_deq)
gref = vjp(2.0 * y_exact)
for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gref)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
# cosine vs exact grads
gexact = jax.grad(lambda p, x: jnp.sum(layer(p, x, pos)**2), argnums=(0,1))(params, x)
for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gexact)):
    a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
    cos = (a @ b) / (np.linalg.norm(a) * np.linalg.norm(b))
    print("cos:", cos)
    assert cos > 0.99
print("fp8 oracle test OK")
