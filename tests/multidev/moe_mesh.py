import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import MeshPlan, ModelConfig, MemoryPlan
from repro.parallel.sharding import ShardingPlanner
from repro.models.moe import moe_init, moe_specs, moe_block, _moe_local, use_ep
from repro.models.layers import ModelContext

cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
                  num_kv_heads=2, d_ff=64, vocab_size=128, num_experts=4, top_k=2,
                  shared_experts=1, capacity_factor=2.0)
key = jax.random.PRNGKey(0)
params = moe_init(key, cfg, jnp.float32)
B, S, D = 8, 16, 32
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5

# dense reference: loop over experts, full capacity (cf high enough -> no drops)
def dense_ref(params, x):
    x2d = x.reshape(-1, D)
    logits = x2d @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2d)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x2d @ params["w1"][e]) * (x2d @ params["w3"][e])
        ye = h @ params["w2"][e]
        w_e = jnp.where(top_i == e, top_p, 0.0).sum(-1)
        out = out + ye * w_e[:, None]
    h = jax.nn.silu(x2d @ params["shared_w1"]) * (x2d @ params["shared_w3"])
    out = out + h @ params["shared_w2"]
    return out.reshape(x.shape)

ref = dense_ref(params, x)

# 1) local path (no mesh)
plan1 = MeshPlan((1,), ("data",))
ctx1 = ModelContext(cfg=cfg, planner=ShardingPlanner(plan1), memory=MemoryPlan(), mesh=None)
out1, aux1 = moe_block(params, ctx1, x)
np.testing.assert_allclose(np.asarray(out1), np.asarray(ref), rtol=1e-4, atol=1e-5)
print("local MoE == dense ref OK, aux:", float(aux1))

# 2) mesh path, EP (E=4 % tp=4... use mesh (2,4): E%4==0 -> EP)
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = MeshPlan((2, 4), ("data", "model"))
planner = ShardingPlanner(plan)
print("use_ep:", use_ep(cfg, planner))
ctx = ModelContext(cfg=cfg, planner=planner, memory=MemoryPlan(), mesh=mesh)
pspecs = moe_specs(cfg, planner)
params_sharded = jax.tree.map(lambda w, s: jax.device_put(w, NamedSharding(mesh, s)), params, pspecs)
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
with mesh:
    out2, aux2 = jax.jit(lambda p, x: moe_block(p, ctx, x))(params_sharded, xs)
np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), rtol=1e-4, atol=1e-5)
print("EP shard_map MoE == dense ref OK, aux:", float(aux2))

# 3) TP-in-expert: experts=3 not divisible by 4
cfg3 = ModelConfig(name="t3", family="moe", num_layers=1, d_model=32, num_heads=4,
                   num_kv_heads=2, d_ff=64, vocab_size=128, num_experts=3, top_k=2,
                   shared_experts=0, capacity_factor=2.0)
params3 = moe_init(jax.random.PRNGKey(2), cfg3, jnp.float32)
def dense_ref3(params, x):
    x2d = x.reshape(-1, D)
    probs = jax.nn.softmax(x2d @ params["router"], -1)
    top_p, top_i = jax.lax.top_k(probs, cfg3.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2d)
    for e in range(3):
        h = jax.nn.silu(x2d @ params["w1"][e]) * (x2d @ params["w3"][e])
        out = out + (h @ params["w2"][e]) * jnp.where(top_i == e, top_p, 0.0).sum(-1)[:, None]
    return out.reshape(x.shape)
ref3 = dense_ref3(params3, x)
ctx3 = ModelContext(cfg=cfg3, planner=planner, memory=MemoryPlan(), mesh=mesh)
ps3 = jax.tree.map(lambda w, s: jax.device_put(w, NamedSharding(mesh, s)), params3, moe_specs(cfg3, planner))
with mesh:
    out3, aux3 = jax.jit(lambda p, x: moe_block(p, ctx3, x))(ps3, xs)
np.testing.assert_allclose(np.asarray(out3), np.asarray(ref3), rtol=1e-4, atol=1e-5)
print("TP-in-expert MoE == dense ref OK")

# 4) gradients flow
def loss(p, x):
    o, aux = moe_block(p, ctx, x)
    return jnp.sum(o**2) + 0.01 * aux
with mesh:
    g = jax.jit(jax.grad(loss))(params_sharded, xs)
gref = jax.grad(lambda p, x: jnp.sum(dense_ref(p, x)**2) + 0.01*0)(params, x)  # aux grad small, test router separately
for k in ["w1","w2","w3","shared_w1"]:
    a, b = np.asarray(g[k]), np.asarray(jax.grad(lambda p,x: jnp.sum(dense_ref(p,x)**2))(params, x)[k])
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
print("MoE gradients == dense ref OK")
