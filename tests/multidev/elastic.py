"""Elastic recovery under a seeded chaos schedule, S=2 pipeline stages.

The ISSUE-6 acceptance scenario: a transient step kill at step k, a
corrupted snapshot shard, and a pipeline stage loss at step m.  The run

  * absorbs the kill through ``retry_step`` (same functional step
    recomputed — the loss curve is untouched),
  * checkpoints through the CheckpointTier runtime (sharded, CRC'd,
    ``ckpt_save`` metered),
  * on the stage loss, replans for the surviving stage via the
    ``plan_memory`` sweep (n_micro=0 → planner-chosen), restores from the
    pool with reshard-on-load (``ckpt_load`` metered), rewinds the data
    stream, and continues.

Pinned against an uninterrupted 2-stage run at the same seed:

  * every step computed *before* the stage loss is bit-identical,
  * every step after recovery matches within the repo's pipeline parity
    tolerance (the surviving-stage partition changes the reduction
    order — same math, different fusion; cf. tests/multidev/pipeline.py
    which pins 2-stage vs unpipelined at rtol=1e-5),
  * ``traffic_report`` shows nonzero ckpt_save/ckpt_load wire bytes and
    the save bytes match the manifest accounting.

Run by tests/test_chaos.py::test_elastic_stage_loss via run_multidev.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=2")
import glob
import json
import tempfile

import jax
import numpy as np

from repro.configs import (ARCHS, MemoryPlan, MeshPlan, PipelinePlan,
                           RunConfig, TrainConfig)
from repro.configs.base import CheckpointPlan, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.train.chaos import ChaosMonkey, ChaosSchedule
from repro.train.elastic import ElasticController
from repro.train.fault import FaultHandler
from repro.train.loop import make_manager, train

S = len(jax.devices())
assert S == 2, S
pipe_mesh = jax.make_mesh((S,), ("pod",))

CFG = ARCHS["smollm-135m"].reduced(dtype="float32", num_layers=2 * S)
STEPS = 10
LOSS_STEP = 5            # stage_loss fires before step index 5


def make_run(pipeline):
    return RunConfig(model=CFG, shape=ShapeConfig("t", 32, 4, "train"),
                     mesh=MeshPlan((1,), ("data",)),
                     memory=MemoryPlan(policy="none"),
                     train=TrainConfig(), pipeline=pipeline)


def run(tag, d, chaos_spec=None):
    tc = TrainConfig(total_steps=STEPS, warmup_steps=2, learning_rate=1e-2,
                     checkpoint_every=2, log_every=1, checkpoint_dir=d,
                     seed=0)
    pipe = PipelinePlan(enabled=True, schedule="1f1b", n_stages=S, n_micro=2)
    runcfg = make_run(pipe)
    model = build_model(runcfg, mesh=None, pipe_mesh=pipe_mesh)
    data = SyntheticLM(CFG, batch=4, seq=32, seed=0)

    chaos = elastic = None
    ckpt = CheckpointPlan(enabled=True, tier="host", codec="none", shards=2)
    mgr = None
    if chaos_spec:
        chaos = ChaosMonkey(ChaosSchedule.parse(chaos_spec), seed=0,
                            retries=2, backoff=0.0)
        mgr = make_manager(model, tc, ckpt, chaos)
        elastic = ElasticController(runcfg, mgr, mesh=None,
                                    pipe_mesh=pipe_mesh)
    curve = []
    hooks = {"on_log": lambda step, m: curve.append((step, m["loss"]))}
    state, _ = train(model, tc, data,
                     fault_handler=FaultHandler(install_signals=False),
                     hooks=hooks, ckpt=ckpt, chaos=chaos, elastic=elastic,
                     mgr=mgr)
    return curve, chaos, elastic, mgr


with tempfile.TemporaryDirectory() as d_ref, \
        tempfile.TemporaryDirectory() as d_chaos:
    ref_curve, _, _, _ = run("ref", d_ref)
    spec = f"kill@2,corrupt@3,stage_loss@{LOSS_STEP}:1"
    chaos_curve, chaos, elastic, mgr = run("chaos", d_chaos, spec)

    # every scheduled event actually delivered
    fired = ",".join(chaos.fired)
    assert "kill@2" in fired and "corrupt@" in fired \
        and f"stage_loss@{LOSS_STEP}" in fired, fired
    assert elastic.recoveries == 1
    assert elastic.run.pipeline.n_stages == S - 1

    # ckpt traffic metered on both directions; save bytes == manifest truth
    tr = mgr.runtime.traffic_report()
    assert tr["ckpt_save"]["wire_bytes"] > 0, tr
    assert tr["ckpt_load"]["wire_bytes"] > 0, tr
    manifests = sorted(glob.glob(os.path.join(d_chaos, "step_*",
                                              "manifest.json")))
    meta = json.load(open(manifests[0]))
    # state size is constant, so total metered save bytes must equal the
    # per-commit manifest accounting times the number of commits
    n_commits = tr["ckpt_save"]["calls"] // len(meta["keys"])
    assert tr["ckpt_save"]["wire_bytes"] == meta["bytes"]["wire"] * n_commits, \
        (tr["ckpt_save"], meta["bytes"], n_commits)

    ref = dict(ref_curve)
    # prefix (before the stage loss): bit-identical to the uninterrupted run
    first = {}
    for s, l in chaos_curve:
        first.setdefault(s, l)
    for s in range(1, LOSS_STEP + 1):
        assert first[s] == ref[s], (s, first[s], ref[s])
    # post-recovery (replayed + new steps on the surviving stage): parity
    # within the repo's pipeline tolerance
    final = dict(chaos_curve)
    for s in range(LOSS_STEP, STEPS + 1):
        np.testing.assert_allclose(final[s], ref[s], rtol=1e-4,
                                   err_msg=f"step {s}")
    print("prefix bit-identical:", [round(first[s], 6)
                                    for s in range(1, LOSS_STEP + 1)])
    print("post-recovery parity:", [(round(final[s], 6), round(ref[s], 6))
                                    for s in range(LOSS_STEP, STEPS + 1)])
print("elastic stage-loss recovery OK")
