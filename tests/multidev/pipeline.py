import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.pipeline import make_pipelined

mesh = jax.make_mesh((4,), ("pod",))
# toy stack: 4 stages, each stage = 2 layers of w*x + b
S, L_per = 4, 2
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, L_per, 8, 8)) * 0.3

def stage_fn(params, x):
    for i in range(L_per):
        x = jnp.tanh(x @ params[i])
    return x

x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))  # 8 rows = 4 microbatches of 2
pipe = make_pipelined(mesh, stage_fn, n_micro=4, axis_name="pod", stage_param_spec=P("pod"))
with mesh:
    y = jax.jit(pipe)(W, x)
# reference: sequential through all stages
ref = x
for s in range(S):
    ref = stage_fn(W[s], ref)
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
print("pipeline == sequential OK")
