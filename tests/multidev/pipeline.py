"""Pipeline schedule parity on a toy stack, S = number of host devices.

Pins, for gpipe and 1f1b at several (including uneven) microbatch counts:
  * forward parity: pipelined == sequential through all stages,
  * loss/grad parity: bit-identical loss and near-exact grads vs the
    sequential per-microbatch reference,
  * a 3-step SGD loss curve identical to the sequential baseline,
  * nonzero act_stash/act_fetch traffic attributed to the stage tier
    (1f1b routes stage inputs through PipelineStageTier hooks),
  * the real-model path: smollm-smoke loss via forward_train_pipelined ==
    the unpipelined baseline (run under 2 devices; needs n_groups % S == 0).

Respects an XLA_FLAGS set by the runner (tests/conftest.py run_multidev
launches this with 2 and with 4 devices).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import MemoryPlan, MeshPlan
from repro.core.runtime import MemoryRuntime
from repro.core.tiers import build_stage_tier
from repro.parallel.pipeline import get_schedule, make_pipelined
from repro.parallel.sharding import ShardingPlanner

S = len(jax.devices())
mesh = jax.make_mesh((S,), ("pod",))
L_per = 2
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, L_per, 8, 8)) * 0.3


def stage_fn(params, x):
    for i in range(L_per):
        x = jnp.tanh(x @ params[i])
    return x


# --- 1. legacy API forward parity (gpipe default) --------------------------
x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
pipe = make_pipelined(mesh, stage_fn, n_micro=4, axis_name="pod",
                      stage_param_spec=P("pod"))
with mesh:
    y = jax.jit(pipe)(W, x)
ref = x
for s in range(S):
    ref = stage_fn(W[s], ref)
np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("pipeline == sequential OK")

# --- 2. schedule loss/grad parity (tree inputs, uneven M) ------------------
plan = MeshPlan((S,), ("pod",))
planner = ShardingPlanner(plan)
memory = MemoryPlan(policy="mcdla")
rt = MemoryRuntime(plan, memory, None, planner=planner,
                   tier=build_stage_tier(memory, planner, None, n_stages=S))

B = 24
xb = jax.random.normal(jax.random.PRNGKey(2), (B, 8))
pos = jnp.arange(B, dtype=jnp.int32)
tgt = jax.random.normal(jax.random.PRNGKey(3), (B, 8))


def stage_tree_fn(params, t):
    return {"h": stage_fn(params, t["h"]), "pos": t["pos"]}


def ref_loss(W, xb, M):
    mb = B // M
    hs = []
    for m in range(M):                       # sequential per-microbatch ref
        h = xb[m * mb:(m + 1) * mb]
        for s in range(S):
            h = stage_fn(W[s], h)
        hs.append(h)
    return jnp.mean((jnp.concatenate(hs) - tgt) ** 2)


for name in ("gpipe", "1f1b"):
    for M in (2, 3, 4, 6):                   # includes M < S and M % S != 0
        sched = get_schedule(name, runtime=rt)
        pipe = make_pipelined(mesh, stage_tree_fn, n_micro=M, schedule=sched)

        def loss(W):
            out = pipe(W, {"h": xb, "pos": pos})
            return jnp.mean((out["h"] - tgt) ** 2)

        l, g = jax.jit(jax.value_and_grad(loss))(W)
        lr, gr = jax.jit(jax.value_and_grad(
            lambda W: ref_loss(W, xb, M)))(W)
        assert float(l) == float(lr), (name, M, float(l), float(lr))
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                   rtol=1e-5, atol=1e-7)
print("schedule loss parity OK")

# --- 3. loss curves: 3 SGD steps, pipelined vs sequential ------------------
for name in ("gpipe", "1f1b"):
    M = S
    sched = get_schedule(name, runtime=rt)
    pipe = make_pipelined(mesh, stage_tree_fn, n_micro=M, schedule=sched)

    def loss_p(W):
        return jnp.mean((pipe(W, {"h": xb, "pos": pos})["h"] - tgt) ** 2)

    step_p = jax.jit(lambda W: (loss_p(W), W - 0.1 * jax.grad(loss_p)(W)))
    step_r = jax.jit(lambda W: (ref_loss(W, xb, M),
                                W - 0.1 * jax.grad(
                                    lambda w: ref_loss(w, xb, M))(W)))
    Wp = Wr = W
    for _ in range(3):
        lp, Wp = step_p(Wp)
        lr, Wr = step_r(Wr)
        assert float(lp) == float(lr), (name, float(lp), float(lr))
print("loss curve parity OK")

# --- 4. stage-tier traffic metered (1f1b hooks) ----------------------------
rep = rt.traffic_report()
assert "pipeline_stage" in rep["tier"], rep["tier"]
assert rep["act_stash"]["calls"] > 0, rep
assert rep["act_fetch"]["calls"] > 0, rep
assert rep["act_stash"]["wire_bytes"] > 0, rep
print("stage tier traffic OK")

# --- 5. real model: pipelined smollm == unpipelined baseline ---------------
from repro.configs import ARCHS, PipelinePlan, RunConfig, TrainConfig
from repro.configs.base import ShapeConfig
from repro.models.model import build_model

cfg = ARCHS["smollm-135m"].reduced(dtype="float32", num_layers=2 * S)
plan1 = MeshPlan((1,), ("data",))
shape = ShapeConfig("t", 32, 4, "train")
tc = TrainConfig()
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(4), (4, 32), 0,
                                 cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0,
                                 cfg.vocab_size),
    "positions": jnp.broadcast_to(jnp.arange(32)[None], (4, 32)),
}
base = build_model(RunConfig(model=cfg, shape=shape, mesh=plan1,
                             memory=memory, train=tc))
params = base.init(jax.random.PRNGKey(0))
l_base, _ = jax.jit(base.loss_fn)(params, batch)
for name in ("gpipe", "1f1b"):
    m = build_model(
        RunConfig(model=cfg, shape=shape, mesh=plan1, memory=memory,
                  train=tc,
                  pipeline=PipelinePlan(enabled=True, schedule=name,
                                        n_micro=2, n_stages=S)),
        mesh=None, pipe_mesh=mesh)
    l_pipe, _ = jax.jit(m.loss_fn)(params, batch)
    np.testing.assert_allclose(float(l_pipe), float(l_base), rtol=1e-5)
print("model pipeline parity OK")
