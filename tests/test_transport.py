"""Wire transport for KV handoffs: framing, corruption, retry, and the
acceptance twin — disagg-over-wire streams bit-identical to the loopback
with ``kv_wire`` metering reconciling exactly against the channel.

Satellite coverage (ISSUE 7): the versioned frame header (schema + CRC32
— a corrupted or mismatched frame raises :class:`WireFormatError` before
any unpickling) and the quota-leak fix (a transport send that fails after
prefill must release the per-uid reservation when the session requeues).
"""
import pickle
import struct

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, RunConfig
from repro.configs.base import MeshPlan, ShapeConfig
from repro.models.model import build_model
from repro.serve.disagg import build_disagg
from repro.serve.engine import Engine, Request
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve import transport as tp
from repro.serve.transport import (Channel, InMemoryChannel, TransportError,
                                   WireFormatError, build_transport,
                                   build_wire_pair, memory_pair, pack_frame,
                                   recv_frame, registered_transports,
                                   run_decode_worker, tcp_pair)

CFG = ARCHS["smollm-135m"].reduced()


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=MeshPlan((1,), ("data",)),
                    memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _prompts(n, base=4):
    return [((np.arange(base + i, dtype=np.int32) * (i + 2) + 1)
             % CFG.vocab_size) for i in range(n)]


def _no_sleep(_):
    raise AssertionError("framing slept on a healthy channel")


# ---------------------------------------------------------------------------
# framing
def test_frame_roundtrip_all_kinds():
    a, b = memory_pair()
    for kind in (tp.K_HANDOFF, tp.K_ACK, tp.K_CANCEL, tp.K_RESULT,
                 tp.K_BYE):
        payload = pickle.dumps({"kind": kind, "blob": b"x" * kind})
        a.send(pack_frame(kind, payload))
        got = recv_frame(b, sleep=_no_sleep)
        assert got == (kind, payload)
    assert recv_frame(b, sleep=_no_sleep) is None   # drained


def test_recv_none_when_idle():
    _, b = memory_pair()
    assert recv_frame(b, sleep=_no_sleep) is None


def test_corrupted_frame_raises_before_unpickle():
    """Satellite: flip one payload byte — the CRC must catch it and the
    error must be raised BEFORE pickle sees the garbage."""
    class Bomb:
        def __reduce__(self):
            return (pytest.fail, ("corrupted frame was unpickled",))

    frame = bytearray(pack_frame(tp.K_RESULT, pickle.dumps(Bomb())))
    frame[tp._HEADER.size + 2] ^= 0xFF
    a, b = memory_pair()
    a.send(bytes(frame))
    with pytest.raises(WireFormatError, match="CRC"):
        recv_frame(b, sleep=_no_sleep)


def test_schema_mismatch_raises():
    payload = pickle.dumps({})
    head = tp._HEADER.pack(tp._MAGIC, tp.SCHEMA_VERSION + 1, tp.K_ACK,
                           len(payload))
    import zlib
    crc = zlib.crc32(payload, zlib.crc32(head)) & 0xFFFFFFFF
    a, b = memory_pair()
    a.send(head + payload + tp._CRC.pack(crc))
    with pytest.raises(WireFormatError, match="schema"):
        recv_frame(b, sleep=_no_sleep)


def test_bad_magic_raises():
    a, b = memory_pair()
    a.send(b"XXzzzzzzz" + b"\0" * 20)
    with pytest.raises(WireFormatError, match="magic"):
        recv_frame(b, sleep=_no_sleep)


def test_partial_reads_reassemble_with_backoff():
    """A fragmented channel (1-byte reads) delivers the frame intact;
    the retry loop backs off exponentially, fault.py-style."""
    a, b = memory_pair(max_chunk=1)
    payload = pickle.dumps(list(range(50)))
    a.send(pack_frame(tp.K_RESULT, payload))
    naps = []
    got = recv_frame(b, retries=3, backoff=0.5, sleep=naps.append)
    assert got == (tp.K_RESULT, payload)
    assert not naps        # bytes kept arriving: no empty read, no sleep


def test_mid_frame_starvation_exhausts_to_transport_error():
    a, b = memory_pair()
    frame = pack_frame(tp.K_ACK, pickle.dumps({"uid": 1}))
    a.send(frame[:len(frame) // 2])     # never send the rest
    naps = []
    with pytest.raises(TransportError, match="partial read"):
        recv_frame(b, retries=3, backoff=0.5, sleep=naps.append)
    assert naps == [0.5, 1.0, 2.0]      # backoff * 2**attempt, no final nap


def test_registry_mirrors_other_registries():
    assert set(registered_transports()) >= {"memory", "tcp"}
    a, b = build_transport("memory")
    a.send(b"hi")
    assert b.recv(10) == b"hi"
    with pytest.raises(KeyError, match="unknown transport"):
        build_transport("carrier-pigeon")


def test_tcp_pair_roundtrips_frames():
    a, b = tcp_pair()
    try:
        payload = pickle.dumps(np.arange(1000))
        a.send(pack_frame(tp.K_HANDOFF, payload))
        got = recv_frame(b, retries=20, backoff=0.001)
        assert got == (tp.K_HANDOFF, payload)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# the acceptance twin: wire == loopback == (by PR 4) colocated/solo
def _drive(pair, prompts, new_tokens=6):
    ss = [pair.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
          for i, p in enumerate(prompts)]
    pair.run()
    return [s.result() for s in ss]


@pytest.mark.parametrize("transport", ["memory", "tcp"])
def test_wire_streams_identical_to_loopback(model_and_params, transport):
    m, params = model_and_params
    prompts = _prompts(5)
    loop = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    want = _drive(loop, prompts)
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", transport=transport)
    assert _drive(wire, prompts) == want
    # counters mirror the loopback queue's cross-checked set
    out = wire.traffic_report()["wire_out"]["transfer"]
    inn = wire.traffic_report()["wire_in"]["transfer"]
    assert out["published"] == inn["published"] == 5
    assert inn["adopted_pages"] == inn["shipped_pages"]
    assert out["depth"] == inn["depth"] == 0


def test_wire_streams_identical_through_fragmented_channel(
        model_and_params):
    """127-byte reads: reassembly never corrupts a page."""
    m, params = model_and_params
    prompts = _prompts(3)
    loop = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    want = _drive(loop, prompts)
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host",
                           channels=memory_pair(max_chunk=127))
    assert _drive(wire, prompts) == want


def test_kv_wire_bytes_reconcile_exactly(model_and_params):
    """Acceptance: summed ``kv_wire`` equals every byte that crossed the
    channel, and the publish/adopt legs see identical payload bytes."""
    m, params = model_and_params
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host")
    _drive(wire, _prompts(4, base=18))
    rep = wire.traffic_report()
    out_wire = rep["wire_out"]["kv_wire"]
    in_wire = rep["wire_in"]["kv_wire"]
    assert out_wire["wire_bytes"] == wire.sender.channel.bytes_sent
    assert in_wire["wire_bytes"] == wire.receiver.channel.bytes_sent
    # raw == wire for frames (already serialized), and the frame leg must
    # carry at least the payload the publish leg metered
    assert out_wire["raw_bytes"] == out_wire["wire_bytes"]
    pub = rep["wire_out"]["kv_publish"]
    adopt = rep["wire_in"]["kv_adopt"]
    assert pub["wire_bytes"] == adopt["wire_bytes"] > 0
    assert pub["raw_bytes"] == adopt["raw_bytes"]
    assert out_wire["wire_bytes"] > pub["wire_bytes"]


def test_wire_codec_compresses_pages(model_and_params):
    """Pages routed through a tenant codec cross the wire compressed:
    fewer wire bytes than raw, streams still close to lossless (fp8 is
    lossy, so only the byte accounting is pinned here)."""
    m, params = model_and_params
    raw = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                          spill="host")
    _drive(raw, _prompts(3, base=18))
    fp8 = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                          spill="host", wire_codec="fp8")
    _drive(fp8, _prompts(3, base=18))
    raw_pub = raw.traffic_report()["wire_out"]["kv_publish"]
    fp8_pub = fp8.traffic_report()["wire_out"]["kv_publish"]
    assert fp8_pub["raw_bytes"] == raw_pub["raw_bytes"]
    assert fp8_pub["wire_bytes"] < raw_pub["wire_bytes"]


def test_cancel_in_transit_over_wire(model_and_params):
    """A session cancelled while parked on the wire is CANCELed on the
    remote, its quota released on both sides."""
    m, params = model_and_params
    quota = QuotaManager(default_quota=TenantQuota(max_pages=64))
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", quota=quota)
    ss = [wire.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(3))]
    # prefill + publish, but do not let decode adopt yet
    wire.prefill.step()
    ss[1].cancel()
    wire.run()
    assert ss[1].finish_reason == "cancelled"
    assert ss[0].done and ss[2].done
    assert quota.charged_uids() == ()


# ---------------------------------------------------------------------------
# satellite: quota release on mid-transfer failure
class FlakyChannel(Channel):
    """Fails the Nth send, transparently wrapping a real channel."""

    def __init__(self, inner, fail_on: int):
        self.inner = inner
        self.fail_on = fail_on
        self.sends = 0

    def send(self, data: bytes) -> None:
        self.sends += 1
        if self.sends == self.fail_on:
            raise TransportError("injected send failure")
        self.inner.send(data)

    def recv(self, n: int) -> bytes:
        return self.inner.recv(n)

    def close(self) -> None:
        self.inner.close()

    @property
    def closed(self) -> bool:
        return self.inner.closed

    @property
    def bytes_sent(self) -> int:
        return self.inner.bytes_sent


def test_publish_failure_releases_quota_and_requeues(model_and_params):
    """Satellite: a transport send that dies mid-handoff must not leak
    the per-uid page reservation — the session requeues, re-charges at
    its next admission, and still finishes with the right stream."""
    m, params = model_and_params
    prompts = _prompts(3)
    loop = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    want = _drive(loop, prompts)

    quota = QuotaManager(default_quota=TenantQuota(max_pages=64))
    tx, rx = memory_pair()
    flaky = FlakyChannel(tx, fail_on=1)     # first handoff send dies
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", quota=quota, channels=(flaky, rx))
    ss = [wire.submit(Request(uid=i, prompt=p, max_new_tokens=6))
          for i, p in enumerate(prompts)]
    wire.prefill.step()                     # publish attempt: uid 0 fails
    assert 0 not in quota.charged_uids(), \
        "failed publish leaked its quota reservation"
    wire.run()
    assert [s.result() for s in ss] == want
    assert quota.charged_uids() == ()
    # the failure never double-registered or dropped the session
    assert all(s.finish_reason == "length" for s in ss)


def test_publish_failure_then_cancel_releases_quota(model_and_params):
    """The other failure path: the requeued session is cancelled before
    its retry — the ledger must still come back empty."""
    m, params = model_and_params
    quota = QuotaManager(default_quota=TenantQuota(max_pages=64))
    tx, rx = memory_pair()
    flaky = FlakyChannel(tx, fail_on=1)
    wire = build_wire_pair(m, params, batch=2, max_len=64, page_size=16,
                           spill="host", quota=quota, channels=(flaky, rx))
    sess = wire.submit(Request(uid=0, prompt=_prompts(1)[0],
                               max_new_tokens=6))
    wire.prefill.step()
    sess.cancel()
    wire.run()
    assert sess.finish_reason == "cancelled"
    assert quota.charged_uids() == ()


# ---------------------------------------------------------------------------
# in-process worker loop (the two-process CI smoke runs the CLI twin)
def test_run_decode_worker_loop(model_and_params):
    """Drive the worker main loop against a WirePrefill half in-process:
    the exact topology of the two-process deployment, minus fork."""
    import threading

    from repro.serve.transport import build_wire_prefill

    m, params = model_and_params
    prompts = _prompts(4)
    loop = build_disagg(m, params, batch=2, max_len=64, page_size=16,
                        transfer="host", spill="host")
    want = _drive(loop, prompts)

    tx, rx = memory_pair()
    half = build_wire_prefill(m, params, tx, max_len=64, page_size=16)
    worker = threading.Thread(
        target=run_decode_worker,
        args=(m, params, rx),
        kwargs=dict(batch=2, max_len=64, page_size=16, spill="host",
                    idle_sleep=0.001))
    worker.start()
    try:
        ss = [half.submit(Request(uid=i, prompt=p, max_new_tokens=6))
              for i, p in enumerate(prompts)]
        half.run()
        assert [s.result() for s in ss] == want
    finally:
        half.close()
        worker.join(timeout=60)
    assert not worker.is_alive()


def test_engine_submit_session_passthrough(model_and_params):
    """Router contract: ``submit(session=)`` keeps the object, its seq,
    and the Request.out_tokens alias."""
    from repro.serve.session import Session

    m, params = model_and_params
    eng = Engine(m, params, batch=2, max_len=64, page_size=16,
                 spill="host")
    req = Request(uid=7, prompt=_prompts(1)[0], max_new_tokens=3)
    sess = Session(request=req, seq=42)
    got = eng.submit(session=sess)
    assert got is sess and got.seq == 42
    eng.run()
    assert sess.done and req.out_tokens is sess.tokens
    assert len(req.out_tokens) == 3
