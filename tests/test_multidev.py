"""Multi-device semantics, each in a subprocess with 8 host devices (the
main test process keeps 1 device per the dry-run isolation rule)."""
import pytest

from conftest import run_multidev


def test_offload_gradients_all_policies():
    out = run_multidev("offload_grads.py")
    assert out.count("OK") >= 5


def test_offload_fp8_oracle():
    out = run_multidev("offload_fp8.py")
    assert "fp8 oracle test OK" in out


def test_moe_mesh_ep_and_tp():
    out = run_multidev("moe_mesh.py")
    assert "EP shard_map MoE == dense ref OK" in out
    assert "TP-in-expert MoE == dense ref OK" in out
    assert "MoE gradients == dense ref OK" in out


def test_ring_and_compressed_collectives():
    out = run_multidev("collectives.py")
    assert "ring_all_reduce == sum OK" in out
    assert "compressed OK" in out


def test_pipeline_equals_sequential():
    out = run_multidev("pipeline.py", devices=4)
    assert "pipeline == sequential OK" in out


def test_sharded_train_step_equivalence():
    out = run_multidev("sharded_train_equiv.py", timeout=900)
    assert "single-device oracle OK" in out
