"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret mode
(assignment requirement: every kernel sweeps shapes/dtypes against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gemm_os import gemm_os, pick_blocks
from repro.kernels.offload_pack import fp8_pack, fp8_unpack
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 2e-1)])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 384, 128, 128, 128),
    (256, 1024, 256, 128, 128, 256),
    (512, 256, 512, 256, 256, 128),
])
def test_gemm_os_sweep(m, k, n, bm, bn, bk, dtype, tol):
    x = jax.random.normal(KEY, (m, k)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    y = gemm_os(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_gemm_pick_blocks_aligned():
    for m, k, n in [(256, 8192, 22528), (4096, 512, 1024), (128, 128, 128)]:
        bm, bn, bk = pick_blocks(m, k, n)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("B,H,Hkv,S,T,d,causal,window", [
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 4, 4, 256, 256, 32, True, 64),
    (2, 8, 2, 96, 160, 64, False, 0),
    (1, 2, 1, 64, 192, 128, True, 0),
])
def test_flash_attention_sweep(B, H, Hkv, S, T, d, causal, window,
                               dtype, tol):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, d)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, d)).astype(dtype)
    o = flash_attention_fwd(q, k, v, causal=causal, window=window,
                            bq=64, bk=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 5)


def test_flash_attention_grads_match_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))

    def loss(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, 0) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4),
                                       (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("BH,S,P,N,c", [
    (3, 64, 16, 8, 16),
    (2, 128, 32, 16, 32),
    (1, 256, 64, 64, 128),
])
def test_ssd_scan_sweep(BH, S, P, N, c, dtype, tol):
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (BH, S, P)) * 0.5).astype(dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    B = (jax.random.normal(ks[2], (BH, S, N)) * 0.4).astype(dtype)
    C = (jax.random.normal(ks[3], (BH, S, N)) * 0.4).astype(dtype)
    y = ssd_scan(x, a, B, C, chunk=c, interpret=True)
    for i in range(BH):
        want, _ = ref.ssd_ref(x[i], a[i], B[i], C[i])
        np.testing.assert_allclose(np.asarray(y[i], np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol * 5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,C,br", [(256, 64, 64), (128, 128, 128),
                                    (512, 32, 64)])
def test_fp8_pack_sweep(R, C, br):
    x = jax.random.normal(KEY, (R, C)) * 5.0
    q, s = fp8_pack(x, block_rows=br, interpret=True)
    qr, sr = ref.fp8_pack_ref(x, br)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(q, np.float32),
                               np.asarray(qr, np.float32))
    y = fp8_unpack(q, s, block_rows=br, dtype=jnp.float32, interpret=True)
    yr = ref.fp8_unpack_ref(qr, sr, br, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.04       # blockwise scales beat the per-tensor bound


@pytest.mark.parametrize("R,C,br", [(256, 64, 64), (128, 128, 128),
                                    (512, 32, 64)])
def test_int8_pack_sweep(R, C, br):
    from repro.kernels.offload_pack import int8_pack, int8_unpack
    x = jax.random.normal(KEY, (R, C)) * 5.0
    q, s = int8_pack(x, block_rows=br, interpret=True)
    qr, sr = ref.int8_pack_ref(x, br)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(q, np.int32),
                                  np.asarray(qr, np.int32))
    y = int8_unpack(q, s, block_rows=br, dtype=jnp.float32, interpret=True)
    yr = ref.int8_unpack_ref(qr, sr, br, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.02       # int8 round-to-nearest, blockwise scale


# ---------------------------------------------------------------------------
# registry-parametrized codec round trips: every codec registered in
# core/compress.py is swept automatically — a future register_codec entry
# is covered the moment it lands, kernel twin and all, without naming it
# here.  Asserts Pallas kernel twin == pure-jnp ref twin on the SAME blocks.
from repro.core.compress import (decode_tensor, encode_tensor,  # noqa: E402
                                 get_codec, registered_codecs)


@pytest.mark.parametrize("name", registered_codecs())
@pytest.mark.parametrize("R,C,br", [(256, 64, 64), (128, 128, 128)])
def test_codec_registry_kernel_vs_ref_blocks(name, R, C, br):
    codec = get_codec(name)
    if not codec.has_kernel:
        pytest.skip(f"codec {name!r} registered without a kernel twin")
    x = jax.random.normal(KEY, (R, C)) * 5.0
    q, s = codec.pack(x, block_rows=br, interpret=True)
    qr, sr = codec.pack_ref(x, br)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(q, np.float32),
                                  np.asarray(qr, np.float32))
    y = codec.unpack(q, s, block_rows=br, dtype=jnp.float32, interpret=True)
    yr = codec.unpack_ref(qr, sr, br, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5)
    # the quantize-dequantize error stays inside the codec's blockwise bound
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.05, (name, rel)


def test_blocksparse_codec_prunes_small_entries():
    """The block-sparse codec's defining property: entries below
    absmax/32 land as EXACT zeros (zero-run-rich payload for a wire-side
    entropy stage), large entries survive int8 quantization, and the
    round trip stays inside the registry error bound."""
    from repro.core import compress as comp
    from repro.kernels.offload_pack import BLOCKSPARSE_TAU
    # the jnp compress path (core, pallas-free imports) and the Pallas
    # kernel twin must prune at the same threshold
    assert comp.BLOCKSPARSE_TAU == BLOCKSPARSE_TAU
    codec = get_codec("blocksparse")
    x = jax.random.normal(KEY, (256, 64)) * 2.0
    q, s = codec.pack(x, block_rows=64, interpret=True)
    xb = np.asarray(x, np.float32).reshape(4, 64, 64)
    absmax = np.abs(xb).max(axis=(1, 2))
    small = np.abs(xb) < (absmax / BLOCKSPARSE_TAU)[:, None, None]
    qb = np.asarray(q, np.int32).reshape(4, 64, 64)
    assert (qb[small] == 0).all()           # pruned to exact zero
    assert (qb[~small] != 0).all()          # kept entries quantize nonzero
    # measurably sparser than the plain int8 twin on the same data
    q_int8, _ = get_codec("int8").pack(x, block_rows=64, interpret=True)
    frac = float((qb == 0).mean())
    frac_int8 = float((np.asarray(q_int8, np.int32) == 0).mean())
    assert frac > frac_int8 and frac >= 0.03
    y = codec.unpack(q, s, block_rows=64, dtype=jnp.float32, interpret=True)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.05


@pytest.mark.parametrize("name", registered_codecs())
def test_codec_registry_tensor_twins(name):
    """encode/decode_tensor (the paged spill path) agree between the
    per-tensor ref path and the single-block kernel path, for any rank."""
    codec = get_codec(name)
    x = jax.random.normal(KEY, (3, 8, 4, 16)) * 3.0
    q, s = encode_tensor(codec, x)
    y = decode_tensor(codec, q, s, jnp.float32)
    assert q.shape == x.shape and y.shape == x.shape
    if codec.has_kernel:
        qk, sk = encode_tensor(codec, x, kernel=True)
        np.testing.assert_array_equal(np.asarray(q, np.float32),
                                      np.asarray(qk, np.float32))
        np.testing.assert_allclose(float(s), float(sk), rtol=1e-6)
        yk = decode_tensor(codec, qk, sk, jnp.float32, kernel=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yk), rtol=1e-6)
    # lossy but bounded
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.08, (name, rel)


# ---------------------------------------------------------------------------
# paged decode attention: in-kernel block-table lookup vs the gather-then-
# decode_attention twin (the tentpole's bit-identity contract)
def _paged_setup(B, H, K, hd, page, pp, seed=0):
    rng = np.random.default_rng(seed)
    P = B * pp + 1                               # frames incl. scratch
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, page, K, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, page, K, hd)), jnp.float32)
    # a permuted map with unowned tail entries routed to scratch — the
    # layout the PagedKVCacheManager actually produces
    pm = rng.permutation(P - 1)[:B * pp].reshape(B, pp).astype(np.int32)
    pm[0, -1] = P - 1                            # one scratch-routed entry
    return q, kp, vp, jnp.asarray(pm)


@pytest.mark.parametrize("page,pp", [(4, 6), (8, 4), (16, 2)])
@pytest.mark.parametrize("window", [0, 9])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (6, 2)])
def test_paged_decode_parity_sweep(page, pp, window, softcap, H, K):
    """Kernel == XLA ref twin across page size x window x softcap x GQA,
    at several cache fills including page boundaries."""
    from repro.kernels.paged_attention import paged_decode_attention
    q, kp, vp, pm = _paged_setup(2, H, K, 32, page, pp)
    for idx in (0, page - 1, page, pp * page - 1):
        got = paged_decode_attention(q, kp, vp, pm, jnp.int32(idx),
                                     window=window, softcap=softcap,
                                     interpret=True)
        want = ref.paged_decode_attention_ref(q, kp, vp, pm, jnp.int32(idx),
                                              window=window, softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("name", registered_codecs())
def test_paged_decode_fused_codec_parity(name):
    """Compressed side-pool pages dequant inside the K/V load exactly as
    decode_tensor would inflate them — for every registered codec."""
    from repro.kernels.paged_attention import paged_decode_attention
    codec = get_codec(name)
    B, H, K, hd, page, pp = 2, 4, 2, 32, 8, 3
    q, kp, vp, pm = _paged_setup(B, H, K, hd, page, pp, seed=1)
    P = kp.shape[0]
    pmn = np.asarray(pm).copy()
    C = 3
    kq = [None] * C
    vq = [None] * C
    ks = np.zeros((C, 1), np.float32)
    vs = np.zeros((C, 1), np.float32)
    for ci, fr in enumerate({int(pmn[0, 0]), int(pmn[1, 1]),
                             int(pmn[0, 1])}):
        qk, sk = encode_tensor(codec, kp[fr])
        qv, sv = encode_tensor(codec, vp[fr])
        kq[ci], ks[ci, 0] = np.asarray(qk), float(sk)
        vq[ci], vs[ci, 0] = np.asarray(qv), float(sv)
        pmn[pmn == fr] = P + ci                  # translate to side ids
    kq, vq = jnp.asarray(np.stack(kq)), jnp.asarray(np.stack(vq))
    ks, vs = jnp.asarray(ks), jnp.asarray(vs)
    pmc = jnp.asarray(pmn)
    idx = jnp.int32(pp * page - 1)
    got = paged_decode_attention(q, kp, vp, pmc, idx, kq_pool=kq,
                                 vq_pool=vq, k_scale=ks, v_scale=vs,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, pmc, idx, kq_pool=kq,
                                          vq_pool=vq, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)
    # the fused path genuinely used the side pool: the raw frames it
    # replaced disagree with the compressed decode
    raw = ref.paged_decode_attention_ref(q, kp, vp, pm, idx)
    assert not np.allclose(np.asarray(got), np.asarray(raw))


def test_paged_decode_inactive_slot_finite():
    """cache_index=-1 masks every row: the output must be finite garbage
    (discarded by the engine mask), never NaN — the decode-path NaN bug."""
    from repro.kernels.paged_attention import paged_decode_attention
    q, kp, vp, pm = _paged_setup(2, 4, 2, 32, 8, 3)
    got = paged_decode_attention(q, kp, vp, pm, jnp.int32(-1),
                                 interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    want = ref.paged_decode_attention_ref(q, kp, vp, pm, jnp.int32(-1))
    assert np.isfinite(np.asarray(want)).all()


def test_paged_attention_impl_registry():
    """ops.paged_attention dispatches by registry flag; unknown impls are
    rejected; both impls agree on the same inputs."""
    q, kp, vp, pm = _paged_setup(1, 2, 2, 16, 4, 2)
    a = ops.paged_attention(q, kp, vp, pm, jnp.int32(5), impl="pallas")
    b = ops.paged_attention(q, kp, vp, pm, jnp.int32(5), impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-6, atol=2e-6)
    with pytest.raises(ValueError):
        ops.set_paged_impl("cuda")
    assert ops._PAGED_IMPL["default"] == "pallas"
    ops.set_paged_impl("xla")
    try:
        c = ops.paged_attention(q, kp, vp, pm, jnp.int32(5))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))
    finally:
        ops.set_paged_impl("pallas")
