"""benchmarks/compare.py: cross-PR bench diffing must stay robust to the
artifacts real runs produce — zero baselines, null values, added/removed
rows — because CI gates on its regression count."""
import json

import pytest

from benchmarks.compare import compare, direction, load_rows


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"suite": "t", "rows": rows}))
    return str(p)


def _row(name, value, note=""):
    return {"name": name, "value": value, "note": note}


def test_zero_baseline_is_annotated_not_inf(tmp_path):
    """A 0-valued baseline must not become an inf/NaN ratio feeding the
    regression flags — it is annotated and never counts as a regression."""
    old = _write(tmp_path, "BENCH_a.json",
                 [_row("serve.x.tok_per_s", 0.0)])
    new = _write(tmp_path, "BENCH_b.json",
                 [_row("serve.x.tok_per_s", 42.0)])
    lines, regressions = compare(old, new)
    assert regressions == 0
    body = "\n".join(lines)
    assert "zero baseline" in body
    assert "inf" not in body and "nan" not in body.lower()


def test_zero_to_zero_is_not_a_regression(tmp_path):
    old = _write(tmp_path, "BENCH_a.json", [_row("x.latency_ms", 0.0)])
    new = _write(tmp_path, "BENCH_b.json", [_row("x.latency_ms", 0.0)])
    lines, regressions = compare(old, new)
    assert regressions == 0


def test_null_value_rows_are_skipped(tmp_path):
    """Benches emit null for 'metric not applicable' (e.g. hit_rate with
    sharing off); a null on either side reports n/a instead of diffing."""
    old = _write(tmp_path, "BENCH_a.json",
                 [_row("s.hit_rate", None), _row("s.tok_per_s", 10.0)])
    new = _write(tmp_path, "BENCH_b.json",
                 [_row("s.hit_rate", 0.5), _row("s.tok_per_s", None)])
    lines, regressions = compare(old, new)
    assert regressions == 0
    body = "\n".join(lines)
    assert body.count("n/a: null value") == 2


def test_load_rows_tolerates_non_numeric(tmp_path):
    p = _write(tmp_path, "BENCH_a.json",
               [_row("a", "not-a-number"), _row("b", "3.5")])
    rows = load_rows(p)
    assert rows["a"][0] is None
    assert rows["b"][0] == pytest.approx(3.5)


def test_real_regression_still_flagged(tmp_path):
    old = _write(tmp_path, "BENCH_a.json", [_row("s.tok_per_s", 100.0)])
    new = _write(tmp_path, "BENCH_b.json", [_row("s.tok_per_s", 50.0)])
    lines, regressions = compare(old, new, threshold=0.05)
    assert regressions == 1
    assert any("REGRESS" in ln for ln in lines)


def test_improvement_not_counted_as_regression(tmp_path):
    old = _write(tmp_path, "BENCH_a.json", [_row("s.latency_ms", 100.0)])
    new = _write(tmp_path, "BENCH_b.json", [_row("s.latency_ms", 50.0)])
    lines, regressions = compare(old, new, threshold=0.05)
    assert regressions == 0
    assert any("improve" in ln for ln in lines)


def test_added_and_removed_rows_reported(tmp_path):
    old = _write(tmp_path, "BENCH_a.json", [_row("gone", 1.0)])
    new = _write(tmp_path, "BENCH_b.json", [_row("fresh", None)])
    lines, regressions = compare(old, new)
    body = "\n".join(lines)
    assert "+ fresh: null" in body
    assert "- gone: 1" in body
    assert regressions == 0


def test_direction_inference():
    assert direction("serve.x.tok_per_s") == +1
    assert direction("decode.latency_ms") == -1
    assert direction("mystery.metric") is None
