"""One function per paper figure/table (the benchmark harness deliverable).

Each returns a list of (name, value, note) rows; benchmarks/run.py prints
them as CSV.  All are driven by the calibrated simulator (sim/), mirroring
the paper's own methodology (§IV).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro import hw
from repro.sim.power import DIMM_OPTIONS, perf_per_watt, system_overhead
from repro.sim.simulator import harmonic_mean, simulate, speedup_table
from repro.sim.topology import (ALL_SYSTEMS, DC_DLA, DC_DLA_GEN4, DC_DLA_O,
                                HC_DLA, MC_DLA_B, MC_DLA_L, MC_DLA_S)
from repro.sim.workloads import WORKLOADS

Row = Tuple[str, float, str]


def _dags(batch=512):
    return {k: f(batch) for k, f in WORKLOADS.items()}


# ---------------------------------------------------------------------------
def fig02_virtualization_overhead() -> List[Row]:
    """Fig 2: device compute grew 20-34x over five generations while PCIe
    stood still -> virtualization overhead explodes."""
    rows: List[Row] = []
    gens = [("K40", 4.3e12), ("M40", 6.8e12), ("P100", 21.2e12),
            ("V100", 125e12), ("next", 250e12)]
    dags = _dags()
    base_time = None
    for name, flops in gens:
        dev = dataclasses.replace(hw.PAPER_DEVICE, peak_flops=flops)
        sys_v = dataclasses.replace(DC_DLA, device=dev, n_devices=1)
        t_virt, t_oracle = [], []
        for dag in dags.values():
            t_virt.append(simulate(dag, sys_v, "dp", n_devices=1).total)
            t_oracle.append(simulate(dag, sys_v, "dp", n_devices=1,
                                     virtualize=False).total)
        overhead = harmonic_mean([v / o for v, o in zip(t_virt, t_oracle)])
        exec_ms = 1e3 * sum(t_oracle) / len(t_oracle)
        if base_time is None:
            base_time = exec_ms
        rows.append((f"fig02.exec_ms.{name}", round(exec_ms, 1),
                     f"speedup vs K40 {base_time / exec_ms:.1f}x"))
        rows.append((f"fig02.virt_overhead.{name}", round(overhead, 2),
                     "x slower with PCIe virtualization"))
    return rows


def fig09_ring_latency() -> List[Row]:
    """Fig 9: collective latency vs ring size (normalized to 2 nodes) —
    adding 8 memory-nodes costs little for reasonably large messages."""
    rows: List[Row] = []
    for sync_bytes, tag in ((8e6, "8MB"), (64e3, "64KB")):
        base = None
        for n in (2, 4, 8, 16):
            sys = dataclasses.replace(MC_DLA_B, ring_nodes=n)
            t = sys.allreduce_time(sync_bytes)
            if base is None:
                base = t
            rows.append((f"fig09.allreduce_{tag}.n{n}", round(t / base, 2),
                         "normalized to 2 nodes"))
    return rows


def fig11_breakdown() -> List[Row]:
    rows: List[Row] = []
    dags = _dags()
    for mode in ("dp", "mp"):
        for sys in (DC_DLA, HC_DLA, MC_DLA_B):
            comp = sync = virt = 0.0
            for dag in dags.values():
                r = simulate(dag, sys, mode)
                comp += r.compute
                sync += r.sync
                virt += r.virt
            tot = comp + sync + virt
            rows.append((f"fig11.{mode}.{sys.name}.compute_frac",
                         round(comp / tot, 3), ""))
            rows.append((f"fig11.{mode}.{sys.name}.sync_frac",
                         round(sync / tot, 3), ""))
            rows.append((f"fig11.{mode}.{sys.name}.virt_frac",
                         round(virt / tot, 3), ""))
    return rows


def fig12_cpu_bandwidth() -> List[Row]:
    rows: List[Row] = []
    for sys in (DC_DLA, HC_DLA, MC_DLA_B):
        fr = [simulate(dag, sys, "dp").cpu_bw_frac
              for dag in _dags().values()]
        rows.append((f"fig12.cpu_bw_frac.{sys.name}",
                     round(max(fr), 3), "max over workloads"))
    return rows


def fig13_speedup() -> List[Row]:
    """THE headline: validates the paper's 2.8x claim (3.5x dp / 2.1x mp)."""
    rows: List[Row] = []
    dags = _dags()
    hm = {}
    for mode in ("dp", "mp"):
        tab = speedup_table(dags, ALL_SYSTEMS, mode)
        for s in ALL_SYSTEMS:
            v = harmonic_mean([tab[w][s.name] for w in tab])
            hm[(mode, s.name)] = v
            rows.append((f"fig13.{mode}.{s.name}", round(v, 2),
                         "hmean speedup over DC-DLA"))
        for w in tab:
            rows.append((f"fig13.{mode}.percell.{w}.MC-DLA(B)",
                         round(tab[w]["MC-DLA(B)"], 2), ""))
    overall = harmonic_mean([hm[("dp", "MC-DLA(B)")],
                             hm[("mp", "MC-DLA(B)")]])
    rows.append(("fig13.MC-DLA(B).overall", round(overall, 2),
                 "paper: 2.8x (dp 3.5 / mp 2.1)"))
    rows.append(("fig13.oracle_fraction.dp",
                 round(hm[("dp", "MC-DLA(B)")] / hm[("dp", "DC-DLA(O)")], 3),
                 "paper: avg 95%"))
    rows.append(("fig13.MCL_over_MCB.dp",
                 round(hm[("dp", "MC-DLA(L)")] / hm[("dp", "MC-DLA(B)")], 3),
                 "paper: 96%"))
    return rows


def fig14_batch_sensitivity() -> List[Row]:
    rows: List[Row] = []
    for batch in (128, 256, 512, 1024):
        sp = []
        for name, fn in WORKLOADS.items():
            dag = fn(batch)
            sp.append(simulate(dag, DC_DLA, "dp").total
                      / simulate(dag, MC_DLA_B, "dp").total)
        rows.append((f"fig14.speedup.batch{batch}",
                     round(harmonic_mean(sp), 2),
                     "paper: avg 2.17x across batches"))
    return rows


def table4_power() -> List[Row]:
    rows: List[Row] = []
    for d in DIMM_OPTIONS:
        ov = system_overhead(d)
        rows.append((f"table4.{d.name.replace(' ', '_')}.node_tdp_w",
                     d.node_tdp_w, f"{d.gb_per_w:.1f} GB/W"))
        rows.append((f"table4.{d.name.replace(' ', '_')}.pool_tb",
                     round(ov["pool_capacity_tb"], 2),
                     f"+{ov['power_increase_frac']:.0%} system power"))
    rows.append(("table4.perf_per_watt.8GB",
                 round(perf_per_watt(2.8, DIMM_OPTIONS[0]), 2),
                 "paper: 2.6x"))
    rows.append(("table4.perf_per_watt.128GB",
                 round(perf_per_watt(2.8, DIMM_OPTIONS[-1]), 2),
                 "paper: 2.1x"))
    return rows


def scalability() -> List[Row]:
    rows: List[Row] = []
    dag = WORKLOADS["VGG-E"]()
    for n in (4, 8):
        for sys, virt in ((DC_DLA, True), (DC_DLA, False), (MC_DLA_B, True)):
            t1 = simulate(dag, sys, "dp", n_devices=1,
                          virtualize=virt).total
            tn = simulate(dag, sys, "dp", n_devices=n,
                          virtualize=virt).total
            tag = f"{sys.name}{'' if virt else '(no-virt)'}"
            rows.append((f"scalability.{tag}.x{n}", round(t1 / tn, 2),
                         "paper: DC 1.3x/2.7x with virt; ~linear without"))
    return rows


ALL_FIGS = [fig02_virtualization_overhead, fig09_ring_latency,
            fig11_breakdown, fig12_cpu_bandwidth, fig13_speedup,
            fig14_batch_sensitivity, table4_power, scalability]
