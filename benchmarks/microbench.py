"""Kernel microbenchmarks (CPU wall-clock for regression tracking; the TPU
roofline terms come from launch/roofline.py, not from these timings)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_microbench() -> List[Row]:
    from repro.kernels import ops, ref
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (256, 512), jnp.float32)
    w = jax.random.normal(key, (512, 256), jnp.float32)
    rows.append(("micro.gemm_os_256x512x256.us",
                 round(_time(lambda a, b: ops.gemm(a, b, bm=128, bn=128,
                                                   bk=128), x, w), 1),
                 "interpret mode (CPU)"))
    rows.append(("micro.gemm_xla_ref.us",
                 round(_time(jax.jit(ref.gemm_ref), x, w), 1), ""))

    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(key, (1, 2, 256, 64))
    v = jax.random.normal(key, (1, 2, 256, 64))
    rows.append(("micro.flash_fwd_256.us",
                 round(_time(lambda *a: ops.flash_attention(*a, True, 0),
                             q, k, v), 1), "interpret mode"))

    xx = jax.random.normal(key, (2, 128, 32)) * 0.5
    a = -jax.nn.softplus(jax.random.normal(key, (2, 128)))
    B = jax.random.normal(key, (2, 128, 16)) * 0.3
    C = jax.random.normal(key, (2, 128, 16)) * 0.3
    rows.append(("micro.ssd_scan_128.us",
                 round(_time(lambda *t: ops.ssd(*t, chunk=32),
                             xx, a, B, C), 1), "interpret mode"))

    big = jax.random.normal(key, (1024, 256))
    rows.append(("micro.fp8_pack_1024x256.us",
                 round(_time(lambda t: ops.fp8_pack(t, block_rows=128)[0],
                             big), 1), "interpret mode"))
    return rows


def tier_microbench() -> List[Row]:
    """stash/fetch round-trip through each registered memory tier
    (single-device CPU wall-clock; the constraint collectives are no-ops
    off-mesh, so this times the data path: codec + copies)."""
    from repro.configs.base import MemoryPlan, MeshPlan
    from repro.core.runtime import MemoryRuntime
    from repro.core.tiers import HostTier, TransferHints

    plan = MeshPlan((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 1024), jnp.float32)
    hints = TransferHints(dtype=jnp.float32)
    rows: List[Row] = []
    for policy, compress in (("none", "none"), ("mcdla", "none"),
                             ("mcdla", "fp8"), ("host", "none"),
                             ("host", "fp8")):
        memory = MemoryPlan(policy=policy, compress=compress)
        runtime = MemoryRuntime(plan, memory)
        tier = runtime.tier

        @jax.jit
        def roundtrip(t, _tier=tier):
            return _tier.fetch(_tier.stash(t, hints), hints)

        note = "stash+fetch round-trip (CPU)"
        inner = tier
        while hasattr(inner, "inner"):
            inner = inner.inner
        if isinstance(inner, HostTier) and not HostTier._supported():
            # don't let a no-op masquerade as a transfer in regression CSVs
            note = "no-op: backend lacks pinned_host (codec only)"
        rows.append((f"micro.tier_{tier.describe()}_256x1024.us",
                     round(_time(roundtrip, x), 1), note))
    return rows
