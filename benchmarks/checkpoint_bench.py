"""Checkpoint-overhead bench: measured save/restore wall-clock through the
CheckpointTier runtime (sync vs async vs codec), the metered ckpt traffic,
and the analytic snapshot-cost model over the DC/HC/MC design points.

Rows follow the repo bench convention ``(name, value, note)``; run via
``python -m benchmarks.run --suite checkpoint`` (emits BENCH_checkpoint.json).
"""
from __future__ import annotations

import tempfile
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _state(param_mb: float = 8.0):
    n = int(param_mb * 1e6 / 4 / 2) // 1024 * 1024   # params + one moment
    w = jnp.arange(n, dtype=jnp.float32).reshape(-1, 1024) / 977
    return {"params": {"w": w}, "opt": {"mu": jnp.zeros_like(w)},
            "step": jnp.array(0, jnp.int32)}


def checkpoint_bench(quick: bool = False) -> List[Row]:
    from repro.configs.base import CheckpointPlan, MemoryPlan, MeshPlan
    from repro.train.checkpoint import CheckpointManager, make_ckpt_runtime

    plan = MeshPlan((1,), ("data",))
    memory = MemoryPlan()
    state = _state(2.0 if quick else 8.0)
    raw_mb = sum(float(x.size) * jnp.dtype(x.dtype).itemsize
                 for x in jax.tree_util.tree_leaves(state)) / 1e6
    rows: List[Row] = [("ckpt.state_size.mb", round(raw_mb, 2), "")]

    variants = [("sync_none", "none", False, 1),
                ("sync_fp8", "fp8", False, 1),
                ("async_none", "none", True, 1),
                ("sharded4_none", "none", False, 4)]
    for tag, codec, async_saves, shards in variants:
        ckpt = CheckpointPlan(enabled=True, tier="host", codec=codec,
                              async_saves=async_saves, shards=shards)
        with tempfile.TemporaryDirectory() as d:
            rt = make_ckpt_runtime(ckpt, plan, memory)
            mgr = CheckpointManager(d, keep=2, runtime=rt, shards=shards,
                                    async_saves=async_saves)
            t0 = time.perf_counter()
            mgr.save(1, {"state": state, "data": None})
            t_issue = time.perf_counter() - t0
            mgr.wait()
            t_save = time.perf_counter() - t0
            t0 = time.perf_counter()
            mgr.restore_latest()
            t_restore = time.perf_counter() - t0
            tr = rt.traffic_report()
            rows.append((f"ckpt.save_{tag}.ms", round(1e3 * t_save, 1),
                         f"{shards} shard(s)"))
            if async_saves:
                rows.append((f"ckpt.save_issue_{tag}.ms",
                             round(1e3 * t_issue, 1),
                             "foreground cost of an async save"))
            rows.append((f"ckpt.restore_{tag}.ms", round(1e3 * t_restore, 1),
                         ""))
            rows.append((f"ckpt.wire_{tag}.mb",
                         round(tr["ckpt_save"]["wire_bytes"] / 1e6, 2),
                         "metered ckpt_save bytes"))

    # analytic: snapshot cost across the paper's design points
    from repro.sim.simulator import simulate_checkpoint
    from repro.sim.topology import ALL_SYSTEMS
    from repro.sim.workloads import WORKLOADS
    dag = WORKLOADS["VGG-E"]()
    state_bytes = sum(l.weight_bytes for l in dag.layers) * 3
    for s in ALL_SYSTEMS:
        for async_saves in (False, True):
            c = simulate_checkpoint(dag, s, state_bytes, mtbf_steps=5000,
                                    async_saves=async_saves)
            mode = "async" if async_saves else "sync"
            rows.append((f"ckpt.sim.{s.name}.{mode}.overhead_frac",
                         round(c.overhead_frac, 6),
                         f"every={c.every} save={c.save_s*1e3:.2f}ms "
                         f"tier={c.tier_kind}"))
    return rows


if __name__ == "__main__":
    for name, value, note in checkpoint_bench(quick=True):
        print(f"{name},{value},{note}")
