"""Serving throughput microbench: tokens/sec through the Engine facade.

CPU wall-clock for regression tracking (like benchmarks/microbench.py; the
TPU numbers come from running launch/serve.py on hardware).  Measures the
full serving stack — scheduler admission, per-length decode groups, cache
manager slot churn, cold-KV spill through the secondary tier — on a
reduced config, for both storage models: monolithic slots and the paged
pool (the paged rows price the gather/scatter the page indirection adds;
the acceptance bar is paged-vs-unpaged within ~10%).

Run directly (``python benchmarks/serve_bench.py``) or import
:func:`serve_bench` from CI.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _build(arch: str = "smollm-135m"):
    import jax
    from repro.configs import (ARCHS, MemoryPlan, RunConfig, TrainConfig)
    from repro.configs.base import MeshPlan, ShapeConfig
    from repro.models.model import build_model

    cfg = ARCHS[arch].reduced()
    run = RunConfig(model=cfg, shape=ShapeConfig("serve", 64, 4, "decode"),
                    mesh=MeshPlan((1,), ("data",)),
                    memory=MemoryPlan(policy="none"), train=TrainConfig())
    model = build_model(run)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _drive(model, params, cfg, *, scheduler, n_requests: int,
           new_tokens: int, batch: int, max_len: int,
           engine=None, on_measure_start=None,
           **engine_kwargs) -> Tuple[float, int, List[float]]:
    """Run one measured batch through an Engine (or a ready DisaggPair).

    Returns ``(wall seconds, tokens decoded, per-request TTFT seconds)``
    — TTFT measured from the measured batch's submission to each
    request's first streamed token.  ``on_measure_start`` fires after the
    warm-up batch drains, so callers can snapshot cumulative counters
    (e.g. transfer-queue pages) and report the measured batch alone.
    """
    from repro.serve.engine import Engine, Request

    eng = engine if engine is not None else Engine(
        model, params, batch=batch, max_len=max_len,
        scheduler=scheduler, **engine_kwargs)
    rng = np.random.default_rng(0)
    first_token = {}

    def submit(uid, toks):
        return eng.submit(
            Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(8,)).astype(np.int32),
                    max_new_tokens=toks),
            on_token=lambda s, t: first_token.setdefault(
                s.uid, time.perf_counter()))

    # warm THIS engine's jitted paths (each storage model compiles its own
    # decode/prefill graphs), then time the measured batch — the row is
    # the serving loop's steady-state tok/s, not XLA compile time.  The
    # warm-up must outlive any preemption quantum so the pause/resume
    # (spill stash/fetch) graphs also compile before the clock starts.
    for i in range(batch + 1):
        submit(1000 + i, 6)
    eng.run()
    first_token.clear()
    if on_measure_start is not None:
        on_measure_start()
    sessions = [submit(i, new_tokens) for i in range(n_requests)]
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    ttft = [first_token[s.uid] - t0 for s in sessions
            if s.uid in first_token]
    return dt, sum(len(s.result()) for s in sessions), ttft


def serve_bench(n_requests: int = 6, batch: int = 2, max_len: int = 64,
                page_size: int = 16) -> List[Row]:
    """Tokens/sec per scheduler policy x storage model.

    Two regimes, both honest about what paging costs and buys:

    * the ``fcfs`` pair decodes 24 tokens/request — decode-weighted, the
      like-for-like storage-overhead comparison (acceptance bar: paged
      within ~10% of unpaged; the page gather/scatter is the only delta).
    * the ``fair_q2`` pair decodes 8 tokens/request so the total page
      demand FITS the pool: preemption churn is then free for the paged
      manager (cold pages readmit copy-free) while the unpaged manager
      round-trips whole slots through the spill tier — the lazy-spill
      upside.  (When demand overcommits the pool the trade reverses:
      per-page eviction churn at CPU dispatch granularity is slower than
      whole-slot spill — measure that deliberately with pages=N.)
    """
    from repro.serve.scheduler import FairScheduler

    cfg, model, params = _build()
    rows: List[Row] = []
    cases = (
        ("fcfs", "fcfs", 24, {}),
        ("fcfs_paged", "fcfs", 24, {"page_size": page_size}),
        ("fair_q2", FairScheduler(quantum=2), 8, {}),
        ("fair_q2_paged", FairScheduler(quantum=2), 8,
         {"page_size": page_size}),
        ("srpt_paged", "srpt", 24, {"page_size": page_size}),
    )
    for name, sched, new_tokens, kwargs in cases:
        dt, total, ttft = _drive(model, params, cfg, scheduler=sched,
                                 n_requests=n_requests,
                                 new_tokens=new_tokens,
                                 batch=batch, max_len=max_len, **kwargs)
        rows.append((f"serve.{name}_{n_requests}req.tok_per_s",
                     round(total / dt, 1),
                     f"{total} tokens, batch={batch} (CPU wall-clock)"))
        if name == "fcfs_paged":
            # this run IS the colocated twin of the disagg rows below —
            # emit its TTFT instead of measuring the same config twice
            rows.append((f"serve.{name}_{n_requests}req.ttft_ms",
                         round(1e3 * sum(ttft) / max(len(ttft), 1), 1),
                         "mean time-to-first-token (colocated)"))
    rows += disagg_bench(n_requests=n_requests, batch=batch, max_len=max_len,
                         page_size=page_size, prebuilt=(cfg, model, params),
                         colocated=False)
    rows += prefix_bench(prebuilt=(cfg, model, params))
    rows += paged_kernel_bench(n_requests=n_requests, batch=batch,
                               max_len=max_len,
                               prebuilt=(cfg, model, params))
    return rows


def paged_kernel_bench(n_requests: int = 6, batch: int = 2,
                       max_len: int = 64,
                       page_sizes: Tuple[int, ...] = (8, 16, 32),
                       prebuilt=None) -> List[Row]:
    """Gather-vs-paged decode across page sizes: the tentpole's number.

    ``gather`` materializes the whole page pool into a contiguous view
    every decode step (the legacy path); ``kernel`` runs the in-place
    paged-attention kernel — the block table rides into the kernel and
    each step touches only the pages its sessions hold.  The
    ``bytes_touched_frac`` row is the metered ratio of page-frame bytes
    the attention actually read vs what the full-pool gather reads (the
    paper's bytes-to-compute vs compute-to-bytes claim, measured)."""
    from repro.serve.engine import Engine

    cfg, model, params = prebuilt if prebuilt else _build()
    rows: List[Row] = []
    for ps in page_sizes:
        io = None
        for kernel in (False, True):
            eng = Engine(model, params, batch=batch, max_len=max_len,
                         scheduler="fcfs", page_size=ps,
                         decode_kernel=kernel)
            dt, total, _ = _drive(model, params, cfg, scheduler="fcfs",
                                  n_requests=n_requests, new_tokens=24,
                                  batch=batch, max_len=max_len, engine=eng)
            mode = "kernel" if kernel else "gather"
            rows.append((f"serve.paged_decode.{mode}_p{ps}.tok_per_s",
                         round(total / dt, 1),
                         f"{total} tokens, batch={batch} (CPU wall-clock)"))
            if kernel:
                io = eng.traffic_report()["decode_io"]
        rows.append((f"serve.paged_decode.kernel_p{ps}.bytes_touched_frac",
                     round(io["bytes_touched"]
                           / max(1, io["bytes_gather_equiv"]), 4),
                     f"{io['pages_touched']}/{io['pages_gather_equiv']} "
                     "page frames read in place vs full-pool gather"))
    return rows


def prefix_bench(page_size: int = 16, max_len: int = 64,
                 prebuilt=None) -> List[Row]:
    """Prefix sharing priced on the shared-prefix Zipf mix
    (sim/workloads.py): the prompt-row hit rate the radix index gets,
    and the admission capacity a fixed small page pool gains when
    matched prefix pages are refcounted instead of duplicated.

    Capacity is peak *concurrently resident* sessions over the run —
    the pool (not the slot count) is sized to be the binding constraint,
    so every page a hit avoids admits more of the burst at once.
    """
    from repro.serve.engine import Engine, Request
    from repro.serve.router import synth_prompt
    from repro.sim.workloads import TrafficSpec, generate_traffic

    cfg, model, params = prebuilt if prebuilt else _build()
    # whole-lifetime demand pinned at 2 pages (24 prompt rows + 8 decode
    # tokens = 32 rows, so decode never grows a page) with a 1.25-page
    # shared head: a matcher binds page 0 read-only and pays for ONE
    # private frame where the non-sharing engine pays for two — with 8
    # frames behind 6 slots the pool, not the slot count, caps the
    # admissible burst, and 8-token decodes keep the burst overlapping
    trace = generate_traffic(TrafficSpec(
        sessions=8, horizon_s=600.0, shared_prefix_frac=1.0,
        prefix_pool=2, prefix_len=20, prompt_mean=24.0, prompt_sigma=0.01,
        prompt_max=24, decode_mean=8.0, decode_sigma=0.01, decode_max=8,
        seed=3))
    rows: List[Row] = []
    got = {}
    for share in (False, True):
        eng = Engine(model, params, batch=6, max_len=max_len,
                     page_size=page_size, pages=8, spill="host",
                     prefix_share=share)
        for s in trace:
            eng.submit(Request(uid=s.uid,
                               prompt=synth_prompt(s, cfg.vocab_size),
                               max_new_tokens=max(1, s.decode_len)))
        peak = 0
        while eng.step() or eng.scheduler.has_waiting():
            peak = max(peak, sum(1 for _ in eng.cache.running()))
        got[share] = (peak, eng.traffic_report().get("prefix"))
    (peak_off, _), (peak_on, prefix) = got[False], got[True]
    # "feature off" must never read as "0% hits": an engine built with
    # prefix_share=True must produce a live prefix section — a missing or
    # disabled one is report-shape drift and fails loudly instead of
    # silently benching hit_rate=0.0
    assert prefix is not None and prefix.get("enabled"), \
        f"prefix-share engine emitted no live prefix report: {prefix!r}"
    rows.append(("serve.prefix_share.hit_rate",
                 round(prefix["hit_rate"], 3),
                 f"{prefix['rows_reused']}/"
                 f"{prefix['rows_prompted']} prompt rows reused, "
                 f"{prefix['forks']} forks (Zipf shared-prefix mix)"))
    rows.append(("serve.prefix_share.admission_capacity_gain",
                 round(peak_on / max(1, peak_off), 2),
                 f"peak concurrent sessions {peak_off} -> {peak_on} "
                 f"at a fixed 8-page pool"))
    return rows


def disagg_bench(n_requests: int = 6, batch: int = 2, max_len: int = 64,
                 page_size: int = 16, new_tokens: int = 24,
                 prebuilt=None, colocated: bool = True) -> List[Row]:
    """Disaggregated vs colocated: steady-state tok/s AND time-to-first-
    token (the number the split is bought for).

    Both drivers serve the same paged storage model; the disagg rows run
    the in-process loopback pair (serve/disagg.py) — prompts prefill on a
    dedicated prefill-role engine and never queue behind decode slots, so
    under a slot-saturating burst the mean TTFT drops even though the
    lockstep loop adds a one-step handoff latency.  Transfer-tier cost is
    honest: every shipped page moves through the tier (metered bytes, CPU
    dispatch per page), which bounds the tok/s delta.

    ``colocated=False`` skips the colocated twin (serve_bench already
    measured that exact config as its ``fcfs_paged`` case).
    """
    from repro.serve.disagg import build_disagg

    cfg, model, params = prebuilt if prebuilt else _build()
    rows: List[Row] = []

    def ms(vals):
        return round(1e3 * sum(vals) / max(len(vals), 1), 1)

    if colocated:
        dt, total, ttft = _drive(model, params, cfg, scheduler="fcfs",
                                 n_requests=n_requests,
                                 new_tokens=new_tokens,
                                 batch=batch, max_len=max_len,
                                 page_size=page_size)
        rows.append((f"serve.colocated_paged_{n_requests}req.tok_per_s",
                     round(total / dt, 1),
                     f"{total} tokens, batch={batch} (CPU wall-clock)"))
        rows.append((f"serve.colocated_paged_{n_requests}req.ttft_ms",
                     ms(ttft), "mean time-to-first-token (colocated)"))

    pair = build_disagg(model, params, batch=batch, max_len=max_len,
                        page_size=page_size, transfer="host", spill="host")
    warm_pages = []
    dt, total, ttft = _drive(
        model, params, cfg, scheduler="fcfs",
        n_requests=n_requests, new_tokens=new_tokens,
        batch=batch, max_len=max_len, engine=pair,
        on_measure_start=lambda: warm_pages.append(
            pair.transfer.shipped_pages))
    shipped = pair.transfer.shipped_pages - warm_pages[0]
    rows.append((f"serve.disagg_{n_requests}req.tok_per_s",
                 round(total / dt, 1),
                 f"{total} tokens, batch={batch}, "
                 f"{shipped} pages shipped (CPU wall-clock)"))
    rows.append((f"serve.disagg_{n_requests}req.ttft_ms",
                 ms(ttft), "mean time-to-first-token (dedicated prefill)"))
    return rows


def router_bench(quick: bool = True) -> List[Row]:
    """PR 7 suite behind BENCH_router.json: the cluster fabric priced
    three ways.

    * ``wire/...`` — the serialization tax: the same requests through the
      in-process loopback pair and through the byte-framed wire pair
      (tok/s each, plus the exact frame bytes the wire moved).
    * ``replay/<policy>/...`` — a scaled-down synthetic traffic replay
      (sim/workloads.py mix) through the REAL router per placement
      policy: tok/s (wall), TTFT in router steps, SLO-miss rate.
    * ``<system>/<policy>/...`` — the analytic sweep of the same policies
      over DC/HC/MC tier configurations at a session count no host can
      replay (sim/simulator.simulate_serving).
    """
    import time as _time

    from repro.serve.engine import Request
    from repro.serve.disagg import build_disagg
    from repro.serve.router import build_router, replay_trace
    from repro.serve.transport import build_wire_pair
    from repro.sim.simulator import serving_table
    from repro.sim.topology import DC_DLA, HC_DLA, MC_DLA_B
    from repro.sim.workloads import TrafficSpec, generate_traffic

    cfg, model, params = _build()
    rows: List[Row] = []
    kw = dict(batch=2, max_len=64, page_size=16, spill="host")
    n_req = 6 if quick else 12

    # --- wire vs loopback ------------------------------------------------
    def drive_pair(pair):
        rng = np.random.default_rng(0)
        for i in range(3):                       # warm the jitted paths
            pair.submit(Request(uid=900 + i, prompt=rng.integers(
                0, cfg.vocab_size, size=(8,)).astype(np.int32),
                max_new_tokens=4))
        pair.run()
        reqs = [Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=(8,)).astype(np.int32),
            max_new_tokens=8) for i in range(n_req)]
        t0 = _time.perf_counter()
        for r in reqs:
            pair.submit(r)
        pair.run()
        dt = _time.perf_counter() - t0
        return n_req * 8 / dt

    rows.append(("wire/loopback_tok_per_s",
                 drive_pair(build_disagg(model, params, transfer="host",
                                         **kw)),
                 "in-process TransferQueue"))
    wire = build_wire_pair(model, params, transport="memory", **kw)
    rows.append(("wire/framed_tok_per_s", drive_pair(wire),
                 "byte-serialized frames (memory channel)"))
    rep = wire.traffic_report()
    rows.append(("wire/kv_wire_bytes",
                 rep["wire_out"]["kv_wire"]["wire_bytes"] +
                 rep["wire_in"]["kv_wire"]["wire_bytes"],
                 "exact frame bytes both directions"))

    # --- real-router replay per policy -----------------------------------
    n_sessions = 12 if quick else 40
    policies = ("least_loaded", "prefix_affinity", "round_robin")
    for policy in policies:
        trace = generate_traffic(TrafficSpec(
            sessions=n_sessions, horizon_s=600.0, prompt_mean=10.0,
            prompt_max=24, decode_mean=6.0, decode_max=10,
            prefix_len=8, seed=7))
        router = build_router(model, params, engines=2, placement=policy,
                              transfer="host", **kw)
        t0 = _time.perf_counter()
        done = replay_trace(router, trace, cfg.vocab_size,
                            arrivals_per_step=2.0)
        dt = _time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        ttft = router.ttft_report()
        slo = router.slo_report()
        rows.append((f"replay/{policy}/tok_per_s", toks / dt,
                     f"{len(done)}/{n_sessions} sessions, 2 engines"))
        rows.append((f"replay/{policy}/ttft_steps", ttft["mean"],
                     f"p99={ttft['p99']}"))
        rows.append((f"replay/{policy}/slo_miss_rate", slo["miss_rate"],
                     f"met={slo['met']} missed={slo['missed']}"))

    # --- analytic sweep at scale -----------------------------------------
    trace = generate_traffic(TrafficSpec(
        sessions=20_000 if quick else 200_000,
        horizon_s=3600.0 if quick else 86_400.0, seed=1))
    for rep in serving_table(trace, [DC_DLA, HC_DLA, MC_DLA_B],
                             policies=policies, engines=8):
        rows.extend(rep.rows())
    return rows


def wire_bench(quick: bool = True) -> List[Row]:
    """PR 10 suite behind BENCH_wire.json: the scale-out wire sweep.

    ``probe/...`` rows measure raw handoff throughput (MB/s, latency and
    exact wire bytes) of one ≥64 MB multi-page handoff per configuration:
    single-stream TCP (the PR 7 baseline — and the before/after of the
    socket-buffer satellite via the ``bufsize`` row), striped TCP at
    2/4/8 streams, the zero-copy shm arena, and the codec leg (int8
    pages cross the wire compressed).  The acceptance bars live here:
    striped(4) ≥ 2x single-stream, shm > striped.  ``sim/...`` rows add
    the analytic stripe-count term over the DC/HC/MC tier configs
    (sim/simulator.simulate_serving ``wire_streams``)."""
    from repro.serve.transport import probe_wire
    from repro.sim.simulator import simulate_serving
    from repro.sim.topology import DC_DLA, HC_DLA, MC_DLA_B
    from repro.sim.workloads import TrafficSpec, generate_traffic

    rows: List[Row] = []
    payload = 64.0
    repeats = 2 if quick else 3

    def add(tag: str, note: str, **kw) -> None:
        r = probe_wire(payload_mb=payload, pages=64, repeats=repeats, **kw)
        rows.append((f"probe/{tag}/mb_per_s", r["mb_per_s"], note))
        rows.append((f"probe/{tag}/handoff_ms", r["handoff_ms"], note))
        rows.append((f"probe/{tag}/wire_bytes", r["wire_bytes"], note))

    add("tcp_s1", "single-stream TCP, 64MB, default bufs",
        transport="tcp", streams=1)
    add("tcp_s1_buf4m", "single-stream TCP, SO_SNDBUF/RCVBUF=4MB",
        transport="tcp", streams=1, bufsize=4 << 20)
    for s in ((4,) if quick else (2, 4, 8)):
        add(f"tcp_s{s}", f"striped TCP, {s} streams, 64MB",
            transport="tcp", streams=s)
    add("tcp_s4_int8", "striped TCP, 4 streams, int8 pages",
        transport="tcp", streams=4, codec="int8")
    add("shm", "zero-copy shared-memory arena, 64MB",
        transport="shm", streams=1)
    if not quick:
        add("memory_s1", "in-process pipe baseline",
            transport="memory", streams=1)

    import dataclasses as _dc

    trace = generate_traffic(TrafficSpec(
        sessions=10_000 if quick else 100_000, horizon_s=3600.0, seed=1))
    # stripe sweep with the wire as the binding cap: feed the *measured*
    # single-stream bandwidth into the analytic model so the sim rows
    # mirror the probe sweep (TTFT includes the handoff leg; tok/s is
    # decode-bound and should NOT move — a sanity check in itself)
    meas = next(v for n, v, _ in rows if n == "probe/tcp_s1/mb_per_s")
    wired = _dc.replace(DC_DLA, wire_stream_bw=meas * 1e6)
    for s in (1, 2, 4, 8):
        rep = simulate_serving(trace, wired, engines=8, wire_streams=s)
        rows.append((f"sim/DC-DLA/s{s}/ttft_mean_ms",
                     rep.ttft_mean_s * 1e3,
                     f"analytic, measured {meas:.0f} MB/s per stream"))
        rows.append((f"sim/DC-DLA/s{s}/ttft_p99_ms",
                     rep.ttft_p99_s * 1e3, "analytic"))
    # at the datacenter NIC default (2.5 GB/s/stream) the backing tier
    # is what differentiates systems once striping removes the wire cap
    for sys_cfg in (DC_DLA, HC_DLA, MC_DLA_B):
        rep = simulate_serving(trace, sys_cfg, engines=8, wire_streams=4)
        rows.append((f"sim/{sys_cfg.name}/s4_nic/ttft_mean_ms",
                     rep.ttft_mean_s * 1e3,
                     "analytic, 2.5 GB/s streams: tier-capped"))
    return rows


if __name__ == "__main__":
    for name, value, note in (serve_bench() + router_bench(quick=True)
                              + wire_bench(quick=True)):
        print(f"{name},{value},{note}")
