"""Serving throughput microbench: tokens/sec through the Engine facade.

CPU wall-clock for regression tracking (like benchmarks/microbench.py; the
TPU numbers come from running launch/serve.py on hardware).  Measures the
full serving stack — scheduler admission, per-length decode groups, cache
manager slot churn and (for the fair-scheduler row) cold-slot spill/fetch
through the secondary tier — on a reduced config.

Run directly (``python benchmarks/serve_bench.py``) or import
:func:`serve_bench` from CI.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _build(arch: str = "smollm-135m"):
    import jax
    from repro.configs import (ARCHS, MemoryPlan, RunConfig, TrainConfig)
    from repro.configs.base import MeshPlan, ShapeConfig
    from repro.models.model import build_model

    cfg = ARCHS[arch].reduced()
    run = RunConfig(model=cfg, shape=ShapeConfig("serve", 64, 4, "decode"),
                    mesh=MeshPlan((1,), ("data",)),
                    memory=MemoryPlan(policy="none"), train=TrainConfig())
    model = build_model(run)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _drive(model, params, cfg, *, scheduler, n_requests: int,
           new_tokens: int, batch: int, max_len: int) -> Tuple[float, int]:
    from repro.serve.engine import Engine, Request

    eng = Engine(model, params, batch=batch, max_len=max_len,
                 scheduler=scheduler)
    rng = np.random.default_rng(0)
    sessions = []
    for i in range(n_requests):
        sessions.append(eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=(8,)).astype(
                np.int32),
            max_new_tokens=new_tokens)))
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return dt, sum(len(s.result()) for s in sessions)


def serve_bench(n_requests: int = 6, new_tokens: int = 8,
                batch: int = 2, max_len: int = 64) -> List[Row]:
    """Tokens/sec for each scheduler policy (fair exercises the spill
    path: more requests than slots, cold slots through the spill tier)."""
    from repro.serve.scheduler import FairScheduler

    cfg, model, params = _build()
    rows: List[Row] = []
    # warm-up: prime the backend compilation caches once.  Each Engine
    # still retraces its own jit wrappers, so rows include that constant
    # cost identically — comparable across schedulers, not jit-free.
    _drive(model, params, cfg, scheduler="fcfs", n_requests=1,
           new_tokens=2, batch=batch, max_len=max_len)
    for name, sched in (("fcfs", "fcfs"),
                        ("fair_q2", FairScheduler(quantum=2))):
        dt, total = _drive(model, params, cfg, scheduler=sched,
                           n_requests=n_requests, new_tokens=new_tokens,
                           batch=batch, max_len=max_len)
        rows.append((f"serve.{name}_{n_requests}req.tok_per_s",
                     round(total / dt, 1),
                     f"{total} tokens, batch={batch} (CPU wall-clock)"))
    return rows


if __name__ == "__main__":
    for name, value, note in serve_bench():
        print(f"{name},{value},{note}")
