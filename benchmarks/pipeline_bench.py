"""Pipeline schedule microbench: step time vs n_micro for gpipe vs 1f1b
with the stage-tier stash on/off, on a toy stack over a CPU host mesh.

CPU wall-clock for regression tracking only (like benchmarks/microbench.py);
the analytic bubble-vs-stall trade lives in core/policy.plan_memory and the
paper-figure timelines in sim/simulator.simulate_pipeline.

Run: PYTHONPATH=src python benchmarks/pipeline_bench.py [--quick]
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import argparse
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]

D = 64          # toy stack width
L_PER = 4       # layers per stage
BATCH = 32


def _toy(n_stages: int):
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (n_stages, L_PER, D, D), jnp.float32) * 0.3
    xb = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D), jnp.float32)
    tgt = jax.random.normal(jax.random.PRNGKey(2), (BATCH, D), jnp.float32)

    def stage_fn(params, t):
        h = t["h"]
        for i in range(L_PER):
            h = jnp.tanh(h @ params[i])
        return {"h": h}

    return W, xb, tgt, stage_fn


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)[1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        out[1].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def pipeline_bench(quick: bool = False) -> List[Row]:
    from repro.configs.base import MemoryPlan, MeshPlan
    from repro.core.runtime import MemoryRuntime
    from repro.core.tiers import build_stage_tier
    from repro.parallel.pipeline import get_schedule, make_pipelined
    from repro.parallel.sharding import ShardingPlanner

    S = len(jax.devices())
    mesh = jax.make_mesh((S,), ("pod",))
    W, xb, tgt, stage_fn = _toy(S)

    plan = MeshPlan((S,), ("pod",))
    planner = ShardingPlanner(plan)
    memory = MemoryPlan(policy="mcdla")
    runtime = MemoryRuntime(
        plan, memory, None, planner=planner,
        tier=build_stage_tier(memory, planner, None, n_stages=S))

    micros = (S,) if quick else (2, S, 2 * S, 4 * S)
    rows: List[Row] = []
    for name in ("gpipe", "1f1b"):
        for stash in (False, True) if name == "1f1b" else (False,):
            rt = runtime if stash else None
            for M in micros:
                if BATCH % M:
                    continue
                sched = get_schedule(name, runtime=rt)
                pipe = make_pipelined(mesh, stage_fn, n_micro=M,
                                      schedule=sched)

                def loss(W):
                    out = pipe(W, {"h": xb})
                    return jnp.mean((out["h"] - tgt) ** 2)

                step = jax.jit(jax.value_and_grad(loss))
                tag = f"{name}{'+stash' if stash else ''}"
                rows.append((f"pipe.{tag}.s{S}.m{M}.us",
                             round(_time(lambda w: step(w), W), 1),
                             "toy stack, CPU host mesh"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="one n_micro per schedule (CI smoke)")
    args = ap.parse_args()
    for name, value, note in pipeline_bench(quick=args.quick):
        print(f"{name},{value},{note}")


if __name__ == "__main__":
    main()
