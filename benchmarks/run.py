"""Single bench entry point: ``python -m benchmarks.run [--suite ...]``.

Runs the requested suites and emits, per suite, a machine-readable
``BENCH_<suite>.json`` (list of ``{"name", "value", "note"}`` records plus
a header with wall-clock and row count) alongside the legacy
``name,value,note`` CSV on stdout.  Suites:

  micro       kernel + tier microbenchmarks
  paper       the paper-figure tables (Fig 11-14, §V-D)
  pipeline    pipeline schedule bench
  serve       serving engine + disaggregated prefill/decode bench
  checkpoint  checkpoint save/restore overhead (measured + analytic)
  router      cluster fabric: wire-vs-loopback tax, real-router traffic
              replay per placement policy, analytic DC/HC/MC sweep
  wire        scale-out wire sweep: single vs striped TCP vs shm MB/s,
              socket-buffer before/after, codec leg, analytic stripe term

Diff two runs' artifacts with ``python -m benchmarks.compare old/ new/``.

CI runs ``--suite micro,checkpoint --quick`` per-push and uploads the JSON
artifacts; the full matrix is the nightly/manual path.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List, Tuple

Row = Tuple[str, float, str]


def _paper_rows() -> List[Row]:
    from benchmarks.paper_figs import ALL_FIGS
    rows: List[Row] = []
    for fig in ALL_FIGS:
        rows.extend(fig())
    return rows


def _micro_rows() -> List[Row]:
    from benchmarks.microbench import kernel_microbench, tier_microbench
    return list(kernel_microbench()) + list(tier_microbench())


def _pipeline_rows(quick: bool) -> List[Row]:
    from benchmarks.pipeline_bench import pipeline_bench
    return pipeline_bench(quick=quick)


def _serve_rows(quick: bool) -> List[Row]:
    from benchmarks.serve_bench import disagg_bench, serve_bench
    n = 4 if quick else 6
    return list(serve_bench(n_requests=n)) + \
        list(disagg_bench(n_requests=n))


def _checkpoint_rows(quick: bool) -> List[Row]:
    from benchmarks.checkpoint_bench import checkpoint_bench
    return checkpoint_bench(quick=quick)


def _router_rows(quick: bool) -> List[Row]:
    from benchmarks.serve_bench import router_bench
    return router_bench(quick=quick)


def _wire_rows(quick: bool) -> List[Row]:
    from benchmarks.serve_bench import wire_bench
    return wire_bench(quick=quick)


SUITES: Dict[str, Callable[[bool], List[Row]]] = {
    "micro": lambda quick: _micro_rows(),
    "paper": lambda quick: _paper_rows(),
    "pipeline": _pipeline_rows,
    "serve": _serve_rows,
    "checkpoint": _checkpoint_rows,
    "router": _router_rows,
    "wire": _wire_rows,
}


def run_suites(names: List[str], quick: bool = False,
               json_dir: str = ".") -> List[Row]:
    all_rows: List[Row] = []
    for name in names:
        t0 = time.time()
        rows = SUITES[name](quick)
        elapsed = round(time.time() - t0, 1)
        payload = {
            "suite": name,
            "quick": quick,
            "elapsed_s": elapsed,
            "n_rows": len(rows),
            "rows": [{"name": n, "value": v, "note": note}
                     for n, v, note in rows],
        }
        path = os.path.join(json_dir or ".", f"BENCH_{name}.json")
        os.makedirs(json_dir or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# suite {name}: {len(rows)} rows in {elapsed}s -> {path}",
              file=sys.stderr)
        all_rows.extend(rows)
    return all_rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", default="paper,micro",
                    help="comma-separated: " + ",".join(SUITES) + " | all")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<suite>.json artifacts")
    args = ap.parse_args()
    names = list(SUITES) if args.suite == "all" else \
        [s.strip() for s in args.suite.split(",") if s.strip()]
    unknown = [s for s in names if s not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suite(s) {unknown}; have {list(SUITES)}")

    t0 = time.time()
    rows = run_suites(names, quick=args.quick, json_dir=args.json_dir)
    print("name,value,note")
    for name, value, note in rows:
        print(f"{name},{value},{note}")
    print(f"# {len(rows)} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
