"""Benchmark harness: one function per paper table/figure + kernel
microbench.  Prints ``name,value,note`` CSV (tee'd to bench_output.txt)."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks.microbench import kernel_microbench, tier_microbench
    from benchmarks.paper_figs import ALL_FIGS

    t0 = time.time()
    rows = []
    for fig in ALL_FIGS:
        rows.extend(fig())
    rows.extend(kernel_microbench())
    rows.extend(tier_microbench())
    print("name,value,note")
    for name, value, note in rows:
        print(f"{name},{value},{note}")
    print(f"# {len(rows)} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
