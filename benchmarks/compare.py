"""Diff two BENCH_*.json artifacts (or artifact directories).

``python -m benchmarks.compare old new [--threshold 0.05]
[--fail-on-regress]`` — the cross-PR trajectory comparison the ROADMAP
names: CI uploads ``BENCH_<suite>.json`` per push (benchmarks/run.py),
and this tool turns two of those uploads into a per-row delta report.

``old``/``new`` each name either one JSON file or a directory; in the
directory case every ``BENCH_*.json`` present in BOTH sides is compared
suite-by-suite.  Rows match by name.  Direction is inferred from the row
name: throughput-like rows (``tok_per_s``, ``speedup``, ``gbps``, ...)
regress when they drop, latency/miss-like rows (``_ms``, ``_s``,
``miss``, ``bubble``, ...) when they rise; unknown names report the
delta but never count as regressions.  ``--fail-on-regress`` exits 1
when any matched row regresses past ``--threshold`` (relative).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

_HIGHER = ("tok_per_s", "tok/s", "speedup", "gbps", "gb_s", "throughput",
           "hit_rate", "util", "ratio_vs", "per_s")
_LOWER = ("_ms", "_s", "_sec", "miss", "bubble", "overhead", "latency",
          "bytes", "stall", "time")


def direction(name: str) -> Optional[int]:
    """+1: higher is better, -1: lower is better, None: no preference."""
    low = name.lower()
    for pat in _HIGHER:
        if pat in low:
            return +1
    for pat in _LOWER:
        if pat in low:
            return -1
    return None


def load_rows(path: str) -> Dict[str, Tuple[Optional[float], str]]:
    """Rows by name.  A null / non-numeric value loads as ``None``
    (benches emit null for 'metric not applicable' — e.g. hit_rate with
    sharing off) and is reported but never diffed."""
    rows: Dict[str, Tuple[Optional[float], str]] = {}
    with open(path) as f:
        payload = json.load(f)
    for r in payload.get("rows", []):
        try:
            val: Optional[float] = float(r["value"])
        except (TypeError, ValueError):
            val = None
        rows[r["name"]] = (val, r.get("note", ""))
    return rows


def pair_files(old: str, new: str) -> List[Tuple[str, str, str]]:
    """(suite, old path, new path) for every suite present in both."""
    if os.path.isfile(old) and os.path.isfile(new):
        suite = os.path.basename(new).replace("BENCH_", "") \
            .replace(".json", "")
        return [(suite, old, new)]
    olds = {os.path.basename(p): p
            for p in glob.glob(os.path.join(old, "BENCH_*.json"))}
    news = {os.path.basename(p): p
            for p in glob.glob(os.path.join(new, "BENCH_*.json"))}
    both = sorted(set(olds) & set(news))
    skipped = sorted(set(olds) ^ set(news))
    if skipped:
        print(f"# only on one side, skipped: {', '.join(skipped)}",
              file=sys.stderr)
    return [(b.replace("BENCH_", "").replace(".json", ""),
             olds[b], news[b]) for b in both]


def compare(old: str, new: str, threshold: float = 0.05
            ) -> Tuple[List[str], int]:
    """Returns (report lines, regression count)."""
    lines: List[str] = []
    regressions = 0
    for suite, old_path, new_path in pair_files(old, new):
        a, b = load_rows(old_path), load_rows(new_path)
        shared = [n for n in b if n in a]
        added = [n for n in b if n not in a]
        removed = [n for n in a if n not in b]
        lines.append(f"== {suite}: {len(shared)} matched, "
                     f"{len(added)} added, {len(removed)} removed ==")
        for name in shared:
            ov, nv = a[name][0], b[name][0]
            if ov is None or nv is None:
                lines.append(f"  {name}: {_fmt(ov)} -> {_fmt(nv)} "
                             "(n/a: null value)")
                continue
            delta = nv - ov
            if ov == 0:
                # a zero baseline has no meaningful relative delta; the
                # old inf/NaN ratio here poisoned the regression flags
                lines.append(f"  {name}: {ov:.6g} -> {nv:.6g} "
                             "(n/a: zero baseline)")
                continue
            rel = delta / abs(ov)
            d = direction(name)
            flag = ""
            if d is not None and abs(rel) > threshold:
                worse = (d > 0) == (delta < 0)
                flag = " REGRESS" if worse else " improve"
                regressions += worse
            lines.append(f"  {name}: {ov:.6g} -> {nv:.6g} "
                         f"({rel:+.1%}){flag}")
        for name in added:
            lines.append(f"  + {name}: {_fmt(b[name][0])}")
        for name in removed:
            lines.append(f"  - {name}: {_fmt(a[name][0])}")
    return lines, regressions


def _fmt(v: Optional[float]) -> str:
    return "null" if v is None else f"{v:.6g}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="BENCH_*.json file or artifact dir")
    ap.add_argument("new", help="BENCH_*.json file or artifact dir")
    ap.add_argument("--threshold", type=float, default=0.05,
                    help="relative delta that counts as a change")
    ap.add_argument("--fail-on-regress", action="store_true",
                    help="exit 1 when a directional row regresses")
    args = ap.parse_args()
    lines, regressions = compare(args.old, args.new, args.threshold)
    try:
        print("\n".join(lines))
    except BrokenPipeError:             # e.g. piped into head
        sys.stderr.close()
        raise SystemExit(1 if regressions and args.fail_on_regress else 0)
    if regressions:
        print(f"# {regressions} regression(s) past "
              f"{args.threshold:.0%}", file=sys.stderr)
        if args.fail_on_regress:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
